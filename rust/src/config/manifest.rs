//! The cluster manifest: one JSON file describing a deployment, shared
//! by `block simulate` and `block serve`.
//!
//! The same document drives both worlds: `simulate --manifest` runs the
//! discrete-event simulator over the manifest's [`ClusterConfig`] (with
//! `n_instances` taken from the instance list), while
//! `serve --role instance|gateway --manifest --index N` brings up the
//! corresponding wire component.  That sharing is what makes the
//! gateway/simulator parity test meaningful — both sides read the
//! identical scheduler, engine, staleness, and seed configuration.
//!
//! ```json
//! {
//!   "schema": "block-cluster/v1",
//!   "cluster": { "scheduler": "block", "frontends": 2, ... },
//!   "instances": ["127.0.0.1:9101", "127.0.0.1:9102"],
//!   "gateways": ["127.0.0.1:9001"],
//!   "backend": "sim",
//!   "clock": "wall",
//!   "time_scale": 1.0,
//!   "artifacts": "artifacts"
//! }
//! ```

use anyhow::{bail, Context, Result};

use crate::config::ClusterConfig;
use crate::util::json::{Json, JsonObj};

/// Which engine substrate instance daemons run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Deterministic sim-clock engine over the roofline cost model (no
    /// artifacts needed; the offline default).
    Sim,
    /// Real transformer compute through the PJRT artifacts.
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "sim" | "sim-clock" => Ok(BackendKind::Sim),
            "pjrt" | "real" => Ok(BackendKind::Pjrt),
            other => bail!("unknown backend '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Sim => "sim",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// How components map time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClockKind {
    /// Wall clock (scaled by `time_scale`) — live serving.
    Wall,
    /// Virtual clock driven by explicit `now` timestamps on requests —
    /// deterministic trace replay (the parity tests' mode).
    Virtual,
}

impl ClockKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "wall" => Ok(ClockKind::Wall),
            "virtual" | "trace" => Ok(ClockKind::Virtual),
            other => bail!("unknown clock '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ClockKind::Wall => "wall",
            ClockKind::Virtual => "virtual",
        }
    }
}

/// Wire-client policy for every HTTP hop in the deployment (gateway →
/// instance clients, plus the gateway's own `/generate` wait budget).
/// Serialized as the manifest's optional `"wire"` section; a manifest
/// without one gets these defaults, which reproduce the pre-hardening
/// behavior (no retries, no hedging) with bounded connect.
#[derive(Debug, Clone, PartialEq)]
pub struct WireConfig {
    /// TCP connect budget, seconds (`<= 0` = OS default).
    pub connect_timeout: f64,
    /// Socket read budget, seconds (`<= 0` = unbounded).
    pub read_timeout: f64,
    /// Socket write budget, seconds (`<= 0` = unbounded).
    pub write_timeout: f64,
    /// Extra attempts for idempotent GET pulls (status/health).
    pub retries: u32,
    /// Retry backoff base, seconds (exponential + deterministic jitter).
    pub backoff_base: f64,
    /// Hedged `/status` pull trigger, seconds (0 = hedging off).
    pub hedge_delay: f64,
    /// Gateway budget for one `/generate` wait, seconds: past it the
    /// client gets a 504 and the request is counted as timed out.
    pub generate_deadline: f64,
}

impl Default for WireConfig {
    fn default() -> Self {
        WireConfig {
            connect_timeout: 5.0,
            read_timeout: 60.0,
            write_timeout: 10.0,
            retries: 0,
            backoff_base: 0.05,
            hedge_delay: 0.0,
            generate_deadline: 50.0,
        }
    }
}

impl WireConfig {
    pub fn validate(&self) -> Result<()> {
        for (name, v) in [
            ("connect_timeout", self.connect_timeout),
            ("read_timeout", self.read_timeout),
            ("write_timeout", self.write_timeout),
            ("backoff_base", self.backoff_base),
            ("hedge_delay", self.hedge_delay),
        ] {
            if !v.is_finite() {
                bail!("wire.{name} must be finite");
            }
        }
        if self.backoff_base < 0.0 {
            bail!("wire.backoff_base must be >= 0");
        }
        if !self.generate_deadline.is_finite() || self.generate_deadline <= 0.0
        {
            bail!("wire.generate_deadline must be finite and > 0");
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("connect_timeout", self.connect_timeout);
        o.insert("read_timeout", self.read_timeout);
        o.insert("write_timeout", self.write_timeout);
        o.insert("retries", self.retries as f64);
        o.insert("backoff_base", self.backoff_base);
        o.insert("hedge_delay", self.hedge_delay);
        o.insert("generate_deadline", self.generate_deadline);
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let d = WireConfig::default();
        let f = |key: &str, dv: f64| -> Result<f64> {
            match j.opt(key) {
                None => Ok(dv),
                Some(v) => v.as_f64(),
            }
        };
        Ok(WireConfig {
            connect_timeout: f("connect_timeout", d.connect_timeout)?,
            read_timeout: f("read_timeout", d.read_timeout)?,
            write_timeout: f("write_timeout", d.write_timeout)?,
            retries: f("retries", d.retries as f64)? as u32,
            backoff_base: f("backoff_base", d.backoff_base)?,
            hedge_delay: f("hedge_delay", d.hedge_delay)?,
            generate_deadline: f("generate_deadline", d.generate_deadline)?,
        })
    }
}

/// A deployable cluster description (see the module doc).
#[derive(Debug, Clone)]
pub struct ClusterManifest {
    pub cluster: ClusterConfig,
    /// Instance daemon addresses (`host:port`), index-aligned with the
    /// scheduler's instance slots.
    pub instances: Vec<String>,
    /// Gateway addresses.
    pub gateways: Vec<String>,
    pub backend: BackendKind,
    pub clock: ClockKind,
    /// Virtual seconds per wall second in wall-clock mode (sim backend
    /// only; >1 fast-forwards the cost model for smoke tests).
    pub time_scale: f64,
    /// Artifact directory for the PJRT backend.
    pub artifacts: String,
    /// Wire-client hardening knobs (timeouts, retries, hedging, the
    /// gateway's `/generate` deadline).
    pub wire: WireConfig,
}

pub const MANIFEST_SCHEMA: &str = "block-cluster/v1";

impl ClusterManifest {
    /// A loopback manifest with `n` sim instances and one gateway —
    /// the starting point tests and `serve_smoke` build on.
    pub fn loopback(cluster: ClusterConfig, n_instances: usize,
                    base_port: u16) -> Self {
        let mut cluster = cluster;
        cluster.n_instances = n_instances.max(1);
        ClusterManifest {
            cluster,
            instances: (0..n_instances.max(1))
                .map(|i| format!("127.0.0.1:{}", base_port + 1 + i as u16))
                .collect(),
            gateways: vec![format!("127.0.0.1:{base_port}")],
            backend: BackendKind::Sim,
            clock: ClockKind::Wall,
            time_scale: 1.0,
            artifacts: "artifacts".to_string(),
            wire: WireConfig::default(),
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.instances.is_empty() {
            bail!("manifest needs at least one instance address");
        }
        if self.gateways.is_empty() {
            bail!("manifest needs at least one gateway address");
        }
        // Address lists must be collision-free: a duplicated address
        // would alias two scheduler slots onto one daemon (double-counted
        // load, double-delivered dispatches), and an instance sharing a
        // gateway's address would route /generate traffic into /enqueue.
        let mut seen = std::collections::HashSet::new();
        for addr in self.instances.iter().chain(self.gateways.iter()) {
            if !seen.insert(addr.as_str()) {
                bail!("duplicate address '{addr}' in manifest \
                       (instances and gateways must be unique)");
            }
        }
        if !self.time_scale.is_finite() || self.time_scale <= 0.0 {
            bail!("time_scale must be finite and > 0");
        }
        if self.cluster.n_instances != self.instances.len() {
            bail!(
                "cluster.n_instances ({}) != instance list length ({})",
                self.cluster.n_instances,
                self.instances.len()
            );
        }
        // Provisioning indexes slots beyond the initial set; every slot
        // it can reach must have a daemon address behind it.
        if self.cluster.provision.enabled
            && self.cluster.provision.max_instances > self.instances.len()
        {
            bail!(
                "provision.max_instances ({}) indexes past the instance \
                 list ({} addresses)",
                self.cluster.provision.max_instances,
                self.instances.len()
            );
        }
        self.wire.validate()?;
        self.cluster.validate()
    }

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("schema", MANIFEST_SCHEMA);
        o.insert("cluster", self.cluster.to_json());
        o.insert(
            "instances",
            Json::Arr(self.instances.iter().map(|a| a.as_str().into()).collect()),
        );
        o.insert(
            "gateways",
            Json::Arr(self.gateways.iter().map(|a| a.as_str().into()).collect()),
        );
        o.insert("backend", self.backend.name());
        o.insert("clock", self.clock.name());
        o.insert("time_scale", self.time_scale);
        o.insert("artifacts", self.artifacts.as_str());
        o.insert("wire", self.wire.to_json());
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        if let Some(s) = j.opt("schema") {
            let s = s.as_str()?;
            if s != MANIFEST_SCHEMA {
                bail!("unsupported manifest schema '{s}'");
            }
        }
        let mut cluster = match j.opt("cluster") {
            Some(c) => ClusterConfig::from_json(c)?,
            None => ClusterConfig::default(),
        };
        let addrs = |key: &str| -> Result<Vec<String>> {
            match j.opt(key) {
                None => Ok(Vec::new()),
                Some(v) => v
                    .as_arr()?
                    .iter()
                    .map(|a| Ok(a.as_str()?.to_string()))
                    .collect(),
            }
        };
        let instances = addrs("instances")?;
        let gateways = addrs("gateways")?;
        // The instance list is authoritative for the slot count: the
        // scheduler's view is index-aligned with it.
        if !instances.is_empty() {
            cluster.n_instances = instances.len();
        }
        let m = ClusterManifest {
            cluster,
            instances,
            gateways,
            backend: match j.opt("backend") {
                None => BackendKind::Sim,
                Some(v) => BackendKind::parse(v.as_str()?)?,
            },
            clock: match j.opt("clock") {
                None => ClockKind::Wall,
                Some(v) => ClockKind::parse(v.as_str()?)?,
            },
            time_scale: match j.opt("time_scale") {
                None => 1.0,
                Some(v) => v.as_f64()?,
            },
            artifacts: match j.opt("artifacts") {
                None => "artifacts".to_string(),
                Some(v) => v.as_str()?.to_string(),
            },
            wire: match j.opt("wire") {
                None => WireConfig::default(),
                Some(v) => WireConfig::from_json(v)?,
            },
        };
        m.validate()?;
        Ok(m)
    }

    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {path}"))?;
        Self::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SchedulerKind, ShardPolicy};

    #[test]
    fn loopback_manifest_is_valid_and_roundtrips() {
        let mut cluster = ClusterConfig::default();
        cluster.scheduler = SchedulerKind::MinQpm;
        cluster.frontends = 2;
        cluster.sync_interval = 0.5;
        cluster.shard_policy = ShardPolicy::Hash;
        let mut m = ClusterManifest::loopback(cluster, 3, 9100);
        m.clock = ClockKind::Virtual;
        m.time_scale = 8.0;
        m.validate().unwrap();
        assert_eq!(m.cluster.n_instances, 3);
        assert_eq!(m.instances.len(), 3);
        assert_eq!(m.gateways, vec!["127.0.0.1:9100".to_string()]);

        let text = m.to_json().to_string_pretty();
        let back = ClusterManifest::from_json(&Json::parse(&text).unwrap())
            .unwrap();
        assert_eq!(back.cluster.scheduler, SchedulerKind::MinQpm);
        assert_eq!(back.cluster.frontends, 2);
        assert_eq!(back.cluster.n_instances, 3);
        assert_eq!(back.instances, m.instances);
        assert_eq!(back.backend, BackendKind::Sim);
        assert_eq!(back.clock, ClockKind::Virtual);
        assert!((back.time_scale - 8.0).abs() < 1e-12);
    }

    #[test]
    fn instance_list_overrides_slot_count() {
        let text = r#"{
            "schema": "block-cluster/v1",
            "cluster": {"n_instances": 99},
            "instances": ["127.0.0.1:9101", "127.0.0.1:9102"],
            "gateways": ["127.0.0.1:9001"]
        }"#;
        let m = ClusterManifest::from_json(&Json::parse(text).unwrap())
            .unwrap();
        assert_eq!(m.cluster.n_instances, 2);
    }

    #[test]
    fn invalid_manifests_rejected() {
        assert!(ClusterManifest::from_json(
            &Json::parse(r#"{"schema": "bogus/v9"}"#).unwrap())
            .is_err());
        let no_instances = r#"{"gateways": ["127.0.0.1:9001"]}"#;
        assert!(ClusterManifest::from_json(
            &Json::parse(no_instances).unwrap())
            .is_err());
        let bad_scale = r#"{
            "instances": ["a:1"], "gateways": ["b:2"], "time_scale": 0
        }"#;
        assert!(ClusterManifest::from_json(&Json::parse(bad_scale).unwrap())
            .is_err());
    }

    #[test]
    fn duplicate_addresses_rejected() {
        let dup_instances = r#"{
            "instances": ["127.0.0.1:9101", "127.0.0.1:9101"],
            "gateways": ["127.0.0.1:9001"]
        }"#;
        assert!(ClusterManifest::from_json(
            &Json::parse(dup_instances).unwrap())
            .is_err());
        let dup_gateways = r#"{
            "instances": ["127.0.0.1:9101"],
            "gateways": ["127.0.0.1:9001", "127.0.0.1:9001"]
        }"#;
        assert!(ClusterManifest::from_json(
            &Json::parse(dup_gateways).unwrap())
            .is_err());
        let cross = r#"{
            "instances": ["127.0.0.1:9101"],
            "gateways": ["127.0.0.1:9101"]
        }"#;
        assert!(ClusterManifest::from_json(&Json::parse(cross).unwrap())
            .is_err());
    }

    #[test]
    fn provision_range_checked_against_instance_list() {
        let mut cluster = ClusterConfig::default();
        cluster.provision.enabled = true;
        cluster.provision.initial_instances = 2;
        cluster.provision.max_instances = 6;
        let m = ClusterManifest::loopback(cluster, 3, 9100);
        let err = m.validate().unwrap_err().to_string();
        assert!(err.contains("max_instances"), "{err}");
        // Enough addresses: valid.
        let mut cluster = ClusterConfig::default();
        cluster.provision.enabled = true;
        cluster.provision.initial_instances = 2;
        cluster.provision.max_instances = 6;
        ClusterManifest::loopback(cluster, 6, 9100).validate().unwrap();
    }

    #[test]
    fn wire_section_roundtrips_and_defaults() {
        // No "wire" section → defaults (back-compat with existing
        // manifests).
        let text = r#"{
            "instances": ["127.0.0.1:9101"],
            "gateways": ["127.0.0.1:9001"]
        }"#;
        let m = ClusterManifest::from_json(&Json::parse(text).unwrap())
            .unwrap();
        assert_eq!(m.wire, WireConfig::default());

        let mut m = ClusterManifest::loopback(ClusterConfig::default(),
                                              2, 9100);
        m.wire.connect_timeout = 0.5;
        m.wire.read_timeout = 2.0;
        m.wire.retries = 2;
        m.wire.hedge_delay = 0.25;
        m.wire.generate_deadline = 10.0;
        m.validate().unwrap();
        let text = m.to_json().to_string_pretty();
        let back = ClusterManifest::from_json(&Json::parse(&text).unwrap())
            .unwrap();
        assert_eq!(back.wire, m.wire);

        m.wire.generate_deadline = 0.0;
        assert!(m.validate().is_err(), "deadline 0 must be rejected");
        m.wire.generate_deadline = 10.0;
        m.wire.backoff_base = -1.0;
        assert!(m.validate().is_err(), "negative backoff must be rejected");
    }

    #[test]
    fn backend_and_clock_parse_names() {
        for b in [BackendKind::Sim, BackendKind::Pjrt] {
            assert_eq!(BackendKind::parse(b.name()).unwrap(), b);
        }
        for c in [ClockKind::Wall, ClockKind::Virtual] {
            assert_eq!(ClockKind::parse(c.name()).unwrap(), c);
        }
        assert!(BackendKind::parse("tpu").is_err());
        assert!(ClockKind::parse("lamport").is_err());
    }
}
