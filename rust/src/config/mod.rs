//! Typed configuration for clusters, engines, schedulers and workloads.
//!
//! Everything an experiment needs is captured in [`ClusterConfig`] +
//! [`WorkloadConfig`]; both round-trip through JSON (`util::json`) so runs
//! are fully describable from a config file (`block experiment --config`).

pub mod manifest;

use anyhow::{anyhow, bail, Context, Result};

use crate::core::hw::{self, GpuProfile, ModelProfile};
use crate::util::json::{Json, JsonObj};
pub use manifest::{BackendKind, ClockKind, ClusterManifest};

/// Local (per-instance) scheduling policy — §2's batching strategies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LocalPolicy {
    /// Original vLLM: prefill-priority, separate prefill/decode batches.
    VllmPrefillPriority,
    /// Sarathi-Serve chunked prefill with a per-step token budget.
    SarathiChunked,
}

impl LocalPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "vllm" | "prefill-priority" => Ok(LocalPolicy::VllmPrefillPriority),
            "sarathi" | "chunked" | "chunked-prefill" => Ok(LocalPolicy::SarathiChunked),
            other => bail!("unknown local policy '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LocalPolicy::VllmPrefillPriority => "vllm",
            LocalPolicy::SarathiChunked => "sarathi",
        }
    }
}

/// Global scheduler selection (§4.2/§5 baselines + Block).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    Random,
    RoundRobin,
    MinQpm,
    InfaasPp,
    LlumnixMinus,
    /// Block with ground-truth lengths.
    Block,
    /// Block* with tagger-estimated lengths.
    BlockStar,
    /// Extension: Block restricted to power-of-two sampled candidates.
    BlockPo2,
}

impl SchedulerKind {
    pub const ALL: [SchedulerKind; 7] = [
        SchedulerKind::Random,
        SchedulerKind::RoundRobin,
        SchedulerKind::MinQpm,
        SchedulerKind::InfaasPp,
        SchedulerKind::LlumnixMinus,
        SchedulerKind::Block,
        SchedulerKind::BlockStar,
    ];

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "random" => Ok(SchedulerKind::Random),
            "round-robin" | "rr" => Ok(SchedulerKind::RoundRobin),
            "min-qpm" | "qpm" => Ok(SchedulerKind::MinQpm),
            "infaas" | "infaas++" => Ok(SchedulerKind::InfaasPp),
            "llumnix" | "llumnix-" => Ok(SchedulerKind::LlumnixMinus),
            "block" => Ok(SchedulerKind::Block),
            "block*" | "block-star" => Ok(SchedulerKind::BlockStar),
            "block-po2" => Ok(SchedulerKind::BlockPo2),
            other => bail!("unknown scheduler '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchedulerKind::Random => "random",
            SchedulerKind::RoundRobin => "round-robin",
            SchedulerKind::MinQpm => "min-qpm",
            SchedulerKind::InfaasPp => "infaas++",
            SchedulerKind::LlumnixMinus => "llumnix-",
            SchedulerKind::Block => "block",
            SchedulerKind::BlockStar => "block*",
            SchedulerKind::BlockPo2 => "block-po2",
        }
    }

    /// Does this scheduler consult the Predictor service?
    pub fn is_predictive(&self) -> bool {
        matches!(self, SchedulerKind::Block | SchedulerKind::BlockStar
                 | SchedulerKind::BlockPo2)
    }

    /// Does this scheduler plan with tagger-estimated lengths?
    pub fn uses_estimates(&self) -> bool {
        matches!(self, SchedulerKind::BlockStar)
    }
}

/// How arrivals are split across scheduler front-ends (distributed
/// deployments, [`ClusterConfig::frontends`] > 1).
///
/// The paper's front-ends are stateless, so any splitter works; the
/// policy only shapes *gateway skew* — how unevenly the independent
/// dispatchers observe the arrival stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardPolicy {
    /// Strict rotation over front-ends (an idealized L4 balancer).
    RoundRobin,
    /// Stable hash of the request id (sticky client→gateway affinity).
    Hash,
    /// Uniform random split — each front-end sees an independent Poisson
    /// thinning of the arrival process.
    Poisson,
}

impl ShardPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "round-robin" | "rr" => Ok(ShardPolicy::RoundRobin),
            "hash" => Ok(ShardPolicy::Hash),
            "poisson" | "random" => Ok(ShardPolicy::Poisson),
            other => bail!("unknown shard policy '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ShardPolicy::RoundRobin => "round-robin",
            ShardPolicy::Hash => "hash",
            ShardPolicy::Poisson => "poisson",
        }
    }
}

/// Per-instance engine configuration (the vLLM knobs §6.1 fixes).
#[derive(Debug, Clone, PartialEq)]
pub struct EngineConfig {
    pub policy: LocalPolicy,
    /// Max sequences in the running batch (paper: 48).
    pub max_batch_size: u32,
    /// Sarathi per-step token budget (paper: 512).
    pub chunk_size: u32,
    /// Paged-attention block size in tokens (vLLM default 16).
    pub block_size: u32,
    /// Total KV blocks; None = derive from GPU/model profiles.
    pub num_blocks: Option<u32>,
    /// Admission watermark fraction (vLLM: 0.01).
    pub watermark: f64,
    /// Prompt+response cap (vLLM max_model_len).
    pub max_model_len: u32,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: LocalPolicy::SarathiChunked,
            max_batch_size: 48,
            chunk_size: 512,
            block_size: 16,
            num_blocks: None,
            watermark: 0.01,
            max_model_len: 2048,
        }
    }
}

/// Dispatcher overhead model (§6.3): Block pays simulation cost; the
/// heuristics pay (smaller) probe/parse cost.  Seconds.
#[derive(Debug, Clone, PartialEq)]
pub struct OverheadConfig {
    /// Fixed per-dispatch cost of a heuristic scheduler (status probe +
    /// JSON parse in the paper's FastAPI prototype).
    pub heuristic_base: f64,
    /// Fixed per-dispatch cost of a predictive dispatch (fan-out +
    /// result merge).
    pub predict_base: f64,
    /// Additional cost per simulated step-sequence in the deepest
    /// predictor (predictors run in parallel → max over instances).
    pub predict_per_step: f64,
    /// Per-dispatch cost of the ack-piggybacked view refresh
    /// (`sync_on_ack`): the instance serializes its status into the
    /// enqueue ack and the front-end parses it.  Free in the original
    /// PR 3 model; charging it is what makes the staleness sweep's
    /// sync-on-ack rows report a real break-even interval.
    pub sync_ack_cost: f64,
}

impl Default for OverheadConfig {
    fn default() -> Self {
        OverheadConfig {
            heuristic_base: 0.012,
            predict_base: 0.035,
            predict_per_step: 6.0e-6,
            sync_ack_cost: 0.003,
        }
    }
}

/// Auto-provisioning (§6.5).
#[derive(Debug, Clone, PartialEq)]
pub struct ProvisionConfig {
    pub enabled: bool,
    /// Latency trigger threshold, seconds (paper: 70).
    pub threshold: f64,
    /// true = "preempt" strategy (trigger on predicted latency);
    /// false = "relief" (trigger on actual latency).
    pub predictive: bool,
    /// Instances available at start.
    pub initial_instances: usize,
    /// Hard cap (backup pool size).
    pub max_instances: usize,
    /// Cold-start delay before a provisioned instance serves, seconds
    /// (model load + engine init).
    pub cold_start: f64,
    /// Minimum spacing between provisioning decisions, seconds.
    pub cooldown: f64,
    /// Drain-based scale-down: an instance idle (empty, nothing
    /// in-transit) for this many seconds is drained and retired.
    /// 0 (the default) disables scale-down entirely.
    pub scale_down_idle: f64,
    /// Scale-down floor: never drain below this many active instances.
    pub min_instances: usize,
}

impl Default for ProvisionConfig {
    fn default() -> Self {
        ProvisionConfig {
            enabled: false,
            threshold: 70.0,
            predictive: true,
            initial_instances: 6,
            max_instances: 10,
            cold_start: 40.0,
            cooldown: 15.0,
            scale_down_idle: 0.0,
            min_instances: 1,
        }
    }
}

/// Fault injection (chaos) knobs — see [`crate::faults`].
///
/// Randomized plans are sampled once before the run from per-component
/// exponentials, so a (config, workload, fault seed) triple replays
/// exactly.  `instance_mttf == 0` and `frontend_mttf == 0` disable the
/// respective fault class; both zero (the default) leaves the subsystem
/// fully inert — the healthy-cluster run, byte for byte.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Mean time to failure per instance, seconds (0 = no instance
    /// failures).
    pub instance_mttf: f64,
    /// Mean time from an instance failure to its rejoin event, seconds.
    pub instance_mttr: f64,
    /// Mean time to crash per front-end, seconds (0 = no crashes).
    /// Front-end 0 never crashes in sampled plans — the designated
    /// survivor.
    pub frontend_mttf: f64,
    /// Failure-detection delay: seconds between an instance dying and
    /// its lost requests re-entering dispatch.
    pub detect_delay: f64,
    /// Cold start charged when a failed instance rejoins (the
    /// [`crate::provision::AutoProvisioner`] pending lifecycle).
    pub rejoin_cold_start: f64,
    /// Mean time from a front-end crash to its restart, seconds.
    /// 0 (the default) makes crashes permanent — the pre-elasticity
    /// behavior.  A restarted front-end comes back with a cold
    /// [`crate::cluster::frontend::StaleClusterView`]: statelessness
    /// means nothing to recover, but the first dispatches pay the
    /// cold-cache cost.
    pub frontend_mttr: f64,
    /// Failure-as-breach pre-warming: treat every `InstanceFail` as a
    /// capacity breach and cold-start the replacement immediately
    /// (`rejoin_cold_start` seconds) instead of waiting for the fault
    /// plan's rejoin.
    pub prewarm: bool,
    /// Mean time to *gray* failure per instance, seconds (0 = no
    /// sampled slowdowns).  A slowed instance still answers — it just
    /// runs every step `slowdown_factor`× slower until the paired
    /// `InstanceRecover` event.
    pub slowdown_mttf: f64,
    /// Mean duration of a sampled slowdown episode, seconds.
    pub slowdown_duration: f64,
    /// Step-time multiplier sampled slowdowns apply (>= 1).
    pub slowdown_factor: f64,
    /// Sliding window for per-fault recovery telemetry, seconds.
    pub report_window: f64,
    /// Seed of the fault-plan RNG (independent of the simulation RNG).
    pub seed: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            instance_mttf: 0.0,
            instance_mttr: 30.0,
            frontend_mttf: 0.0,
            detect_delay: 0.25,
            rejoin_cold_start: 5.0,
            frontend_mttr: 0.0,
            prewarm: false,
            slowdown_mttf: 0.0,
            slowdown_duration: 20.0,
            slowdown_factor: 3.0,
            report_window: 15.0,
            seed: 13,
        }
    }
}

impl FaultConfig {
    /// Does this config inject any faults at all?
    pub fn enabled(&self) -> bool {
        self.instance_mttf > 0.0 || self.frontend_mttf > 0.0
            || self.slowdown_mttf > 0.0
    }

    pub fn validate(&self) -> Result<()> {
        for (name, v) in [
            ("instance_mttf", self.instance_mttf),
            ("frontend_mttf", self.frontend_mttf),
            ("detect_delay", self.detect_delay),
            ("rejoin_cold_start", self.rejoin_cold_start),
            ("frontend_mttr", self.frontend_mttr),
            ("slowdown_mttf", self.slowdown_mttf),
        ] {
            if !v.is_finite() || v < 0.0 {
                bail!("faults.{name} must be finite and >= 0");
            }
        }
        if !self.instance_mttr.is_finite() || self.instance_mttr <= 0.0 {
            bail!("faults.instance_mttr must be finite and > 0");
        }
        if !self.slowdown_duration.is_finite() || self.slowdown_duration <= 0.0
        {
            bail!("faults.slowdown_duration must be finite and > 0");
        }
        if !self.slowdown_factor.is_finite() || self.slowdown_factor < 1.0 {
            bail!("faults.slowdown_factor must be finite and >= 1");
        }
        if !self.report_window.is_finite() || self.report_window <= 0.0 {
            bail!("faults.report_window must be finite and > 0");
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("instance_mttf", self.instance_mttf);
        o.insert("instance_mttr", self.instance_mttr);
        o.insert("frontend_mttf", self.frontend_mttf);
        o.insert("detect_delay", self.detect_delay);
        o.insert("rejoin_cold_start", self.rejoin_cold_start);
        o.insert("frontend_mttr", self.frontend_mttr);
        o.insert("prewarm", self.prewarm);
        o.insert("slowdown_mttf", self.slowdown_mttf);
        o.insert("slowdown_duration", self.slowdown_duration);
        o.insert("slowdown_factor", self.slowdown_factor);
        o.insert("report_window", self.report_window);
        o.insert("seed", self.seed);
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut c = FaultConfig::default();
        if let Some(v) = j.opt("instance_mttf") {
            c.instance_mttf = v.as_f64()?;
        }
        if let Some(v) = j.opt("instance_mttr") {
            c.instance_mttr = v.as_f64()?;
        }
        if let Some(v) = j.opt("frontend_mttf") {
            c.frontend_mttf = v.as_f64()?;
        }
        if let Some(v) = j.opt("detect_delay") {
            c.detect_delay = v.as_f64()?;
        }
        if let Some(v) = j.opt("rejoin_cold_start") {
            c.rejoin_cold_start = v.as_f64()?;
        }
        if let Some(v) = j.opt("frontend_mttr") {
            c.frontend_mttr = v.as_f64()?;
        }
        if let Some(v) = j.opt("prewarm") {
            c.prewarm = v.as_bool()?;
        }
        if let Some(v) = j.opt("slowdown_mttf") {
            c.slowdown_mttf = v.as_f64()?;
        }
        if let Some(v) = j.opt("slowdown_duration") {
            c.slowdown_duration = v.as_f64()?;
        }
        if let Some(v) = j.opt("slowdown_factor") {
            c.slowdown_factor = v.as_f64()?;
        }
        if let Some(v) = j.opt("report_window") {
            c.report_window = v.as_f64()?;
        }
        if let Some(v) = j.opt("seed") {
            c.seed = v.as_usize()? as u64;
        }
        Ok(c)
    }
}

/// Predictive straggler detection — the residual tracker that drives
/// the `Degraded` lifecycle edge (see [`crate::faults::residual`]).
///
/// The detector feeds on the predicted-vs-actual e2e ratio of every
/// completion: Block already computes a prediction per dispatch, so the
/// residual is a failure signal for free.  Disabled (the default) the
/// subsystem is fully inert — zero-degradation configs reproduce
/// healthy runs byte for byte.
#[derive(Debug, Clone, PartialEq)]
pub struct DetectConfig {
    /// Master switch for residual-driven quarantine.
    pub enabled: bool,
    /// EWMA smoothing weight of the newest residual sample, in (0, 1].
    pub alpha: f64,
    /// Quarantine when the EWMA residual ratio exceeds this (e.g. 2.5 =
    /// completions run 2.5× slower than predicted).
    pub trip: f64,
    /// Below this ratio the instance reports a clean perf factor of 1
    /// (hysteresis gap between trip and clear).
    pub clear: f64,
    /// Minimum completions observed before the tracker may trip
    /// (a single unlucky request must not quarantine a healthy host).
    pub min_samples: u64,
    /// Probation: seconds a Degraded slot sits quarantined before it is
    /// restored to Active (and its tracker reset to collect fresh
    /// evidence).
    pub restore_after: f64,
}

impl Default for DetectConfig {
    fn default() -> Self {
        DetectConfig {
            enabled: false,
            alpha: 0.3,
            trip: 2.5,
            clear: 1.3,
            min_samples: 3,
            restore_after: 15.0,
        }
    }
}

impl DetectConfig {
    pub fn validate(&self) -> Result<()> {
        if !self.alpha.is_finite() || !(0.0..=1.0).contains(&self.alpha)
            || self.alpha == 0.0
        {
            bail!("detect.alpha must be in (0, 1]");
        }
        if !self.trip.is_finite() || self.trip <= 1.0 {
            bail!("detect.trip must be finite and > 1");
        }
        if !self.clear.is_finite() || self.clear < 1.0
            || self.clear > self.trip
        {
            bail!("detect.clear must be in [1, trip]");
        }
        if self.min_samples == 0 {
            bail!("detect.min_samples must be > 0");
        }
        if !self.restore_after.is_finite() || self.restore_after <= 0.0 {
            bail!("detect.restore_after must be finite and > 0");
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("enabled", self.enabled);
        o.insert("alpha", self.alpha);
        o.insert("trip", self.trip);
        o.insert("clear", self.clear);
        o.insert("min_samples", self.min_samples);
        o.insert("restore_after", self.restore_after);
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut c = DetectConfig::default();
        if let Some(v) = j.opt("enabled") {
            c.enabled = v.as_bool()?;
        }
        if let Some(v) = j.opt("alpha") {
            c.alpha = v.as_f64()?;
        }
        if let Some(v) = j.opt("trip") {
            c.trip = v.as_f64()?;
        }
        if let Some(v) = j.opt("clear") {
            c.clear = v.as_f64()?;
        }
        if let Some(v) = j.opt("min_samples") {
            c.min_samples = v.as_usize()? as u64;
        }
        if let Some(v) = j.opt("restore_after") {
            c.restore_after = v.as_f64()?;
        }
        Ok(c)
    }
}

/// How much the decision tracer records per dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceLevel {
    /// No decision records at all.
    Off,
    /// One record per dispatch decision (candidate set, argmin,
    /// back-annotated actual latency).
    Decisions,
    /// Decisions plus per-step flight milestones (largest artifacts).
    Full,
}

impl TraceLevel {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "off" => TraceLevel::Off,
            "decisions" => TraceLevel::Decisions,
            "full" => TraceLevel::Full,
            other => bail!("unknown trace level '{other}' \
                            (off|decisions|full)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TraceLevel::Off => "off",
            TraceLevel::Decisions => "decisions",
            TraceLevel::Full => "full",
        }
    }
}

/// Observability tier (`obs` section): the request flight recorder, the
/// scheduler decision tracer, and the live metrics registry.  All three
/// default to off, and when off the tier is fully inert — disabled-obs
/// runs reproduce current runs byte for byte (pinned by
/// `obs_disabled_reproduces_baseline_exactly`).
#[derive(Debug, Clone, PartialEq)]
pub struct ObsConfig {
    /// Flight-recorder ring capacity (events kept; older ones are
    /// dropped and counted).  0 disables the recorder.
    pub ring_capacity: usize,
    /// Decision-trace verbosity (`simulate --trace` flips this on).
    pub trace: TraceLevel,
    /// Live metrics registry (counters/gauges/histograms snapshotted
    /// into `SimResult` and served at `GET /metrics` on the wire).
    pub metrics: bool,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            ring_capacity: 65_536,
            trace: TraceLevel::Off,
            metrics: false,
        }
    }
}

impl ObsConfig {
    /// True when any obs component records anything.  The simulator
    /// consults this once at init: `false` means no ObsState is built
    /// and every hook is a no-op on a `None`.
    pub fn any_enabled(&self) -> bool {
        self.trace != TraceLevel::Off || self.metrics
    }

    /// True when lifecycle flight events should be recorded.
    pub fn flight_enabled(&self) -> bool {
        self.trace != TraceLevel::Off && self.ring_capacity > 0
    }

    pub fn validate(&self) -> Result<()> {
        if self.flight_enabled() && self.ring_capacity < 16 {
            bail!("obs.ring_capacity must be 0 (off) or >= 16");
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("ring_capacity", self.ring_capacity);
        o.insert("trace", self.trace.name());
        o.insert("metrics", self.metrics);
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut c = ObsConfig::default();
        if let Some(v) = j.opt("ring_capacity") {
            c.ring_capacity = v.as_usize()?;
        }
        if let Some(v) = j.opt("trace") {
            c.trace = TraceLevel::parse(v.as_str()?)?;
        }
        if let Some(v) = j.opt("metrics") {
            c.metrics = v.as_bool()?;
        }
        Ok(c)
    }
}

/// Whole-cluster configuration.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub n_instances: usize,
    pub gpu: GpuProfile,
    pub model: ModelProfile,
    pub engine: EngineConfig,
    pub scheduler: SchedulerKind,
    pub overhead: OverheadConfig,
    pub provision: ProvisionConfig,
    /// Predictor replicas per instance (paper: 16) — bounds parallel
    /// prediction throughput in the serving-mode coordinator.
    pub predictor_replicas: usize,
    /// Stateless scheduler front-ends sharing the cluster (`--frontends`).
    /// 1 reproduces the centralized single-dispatcher deployment exactly.
    pub frontends: usize,
    /// Seconds between a front-end's periodic view pulls
    /// (`--sync-interval`).  0 means every arrival sees a perfectly fresh
    /// view — the centralized assumption the paper argues against, and the
    /// pre-distributed behavior of this simulator.
    pub sync_interval: f64,
    /// How arrivals are split across front-ends (`--shard`); irrelevant
    /// when `frontends == 1`.
    pub shard_policy: ShardPolicy,
    /// Piggyback a single-instance view refresh on every dispatch ack
    /// (`--sync-on-ack`): the acking instance reports its post-enqueue
    /// state to the dispatching front-end.  Only meaningful with
    /// `sync_interval > 0`.  Charged per dispatch through
    /// [`OverheadConfig::sync_ack_cost`].
    pub sync_on_ack: bool,
    /// Stale-view local echo (`--local-echo`): a front-end replays its
    /// own dispatches since its last view sync onto its stale view as
    /// extra in-transit load, recovering most of the centralized
    /// in-transit accounting with zero additional synchronization.
    /// Only meaningful with `sync_interval > 0`.
    pub local_echo: bool,
    /// Fault injection (`--instance-mttf` etc.); inert by default.
    pub faults: FaultConfig,
    /// Predictive straggler detection (`--detect`); inert by default.
    pub detect: DetectConfig,
    /// Observability tier (`--trace`, manifest `obs` section); inert by
    /// default.
    pub obs: ObsConfig,
    /// Worker threads for Block's per-candidate prediction fan-out
    /// (`--jobs`).  1 = serial; any value produces bit-identical
    /// scheduling decisions — the argmin is ordered by
    /// (predicted e2e, instance index).
    pub jobs: usize,
    /// Event-loop shards for the mega-scale runner (`--shards`).
    /// 1 = the legacy single-heap loop; `k > 1` partitions instances
    /// into `k` contiguous chunks whose engine events advance in
    /// parallel inside conservative time windows, coordinated at
    /// window barriers.  Any value produces byte-identical results
    /// (pinned by `prop_sharded_parity`); shard workers share the
    /// `--jobs` thread budget.
    pub shards: usize,
    /// Maximum virtual-time span of one conservative window, seconds
    /// (`--window`).  Only meaningful with `shards > 1`.  `0` degrades
    /// to fully serialized merged execution — the always-correct
    /// fallback the parity suite pins the windowed path against.
    pub window: f64,
    /// Latency-model noise applied by the *engine* execution (the gap the
    /// predictor cannot see); 0 disables.
    pub exec_noise: f64,
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            n_instances: 12,
            gpu: hw::A30,
            model: hw::LLAMA2_7B,
            engine: EngineConfig::default(),
            scheduler: SchedulerKind::Block,
            overhead: OverheadConfig::default(),
            provision: ProvisionConfig::default(),
            predictor_replicas: 16,
            frontends: 1,
            sync_interval: 0.0,
            shard_policy: ShardPolicy::RoundRobin,
            sync_on_ack: false,
            local_echo: false,
            faults: FaultConfig::default(),
            detect: DetectConfig::default(),
            obs: ObsConfig::default(),
            jobs: 1,
            shards: 1,
            window: 1.0,
            exec_noise: 0.06,
            seed: 42,
        }
    }
}

impl ClusterConfig {
    /// Resolved number of KV blocks per instance.
    pub fn kv_blocks(&self) -> u32 {
        self.engine.num_blocks.unwrap_or_else(|| {
            hw::num_kv_blocks(&self.gpu, &self.model, self.engine.block_size, 0.9)
        })
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_instances == 0 {
            bail!("n_instances must be > 0");
        }
        if self.engine.max_batch_size == 0 {
            bail!("max_batch_size must be > 0");
        }
        if self.engine.chunk_size == 0 {
            bail!("chunk_size must be > 0");
        }
        if self.engine.block_size == 0 {
            bail!("block_size must be > 0");
        }
        if !(0.0..1.0).contains(&self.engine.watermark) {
            bail!("watermark must be in [0,1)");
        }
        if self.kv_blocks() < 4 {
            bail!("kv blocks too small: {}", self.kv_blocks());
        }
        let max_len_blocks = self.engine.max_model_len.div_ceil(self.engine.block_size);
        if max_len_blocks > self.kv_blocks() {
            bail!("a max-length request cannot fit in KV memory");
        }
        if self.provision.enabled
            && self.provision.max_instances < self.provision.initial_instances
        {
            bail!("max_instances < initial_instances");
        }
        if !self.provision.scale_down_idle.is_finite()
            || self.provision.scale_down_idle < 0.0
        {
            bail!("provision.scale_down_idle must be finite and >= 0");
        }
        if self.provision.enabled
            && self.provision.scale_down_idle > 0.0
            && self.provision.min_instances == 0
        {
            bail!("provision.min_instances must be > 0 when scale-down is on");
        }
        if self.jobs == 0 {
            bail!("jobs must be > 0 (1 = serial fan-out)");
        }
        if self.shards == 0 {
            bail!("shards must be > 0 (1 = single-heap event loop)");
        }
        if !self.window.is_finite() || self.window < 0.0 {
            bail!("window must be finite and >= 0 (0 = serialized merge)");
        }
        if self.frontends == 0 {
            bail!("frontends must be > 0 (1 = centralized dispatch)");
        }
        if !self.sync_interval.is_finite() || self.sync_interval < 0.0 {
            bail!("sync_interval must be finite and >= 0 (0 = always fresh)");
        }
        self.faults.validate()?;
        self.detect.validate()?;
        self.obs.validate()?;
        Ok(())
    }

    // ---- JSON round-trip --------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("n_instances", self.n_instances);
        o.insert("gpu", self.gpu.name);
        o.insert("model", self.model.name);
        o.insert("scheduler", self.scheduler.name());
        let mut e = JsonObj::new();
        e.insert("policy", self.engine.policy.name());
        e.insert("max_batch_size", self.engine.max_batch_size as u64);
        e.insert("chunk_size", self.engine.chunk_size as u64);
        e.insert("block_size", self.engine.block_size as u64);
        if let Some(n) = self.engine.num_blocks {
            e.insert("num_blocks", n as u64);
        }
        e.insert("watermark", self.engine.watermark);
        e.insert("max_model_len", self.engine.max_model_len as u64);
        o.insert("engine", e);
        let mut ov = JsonObj::new();
        ov.insert("heuristic_base", self.overhead.heuristic_base);
        ov.insert("predict_base", self.overhead.predict_base);
        ov.insert("predict_per_step", self.overhead.predict_per_step);
        ov.insert("sync_ack_cost", self.overhead.sync_ack_cost);
        o.insert("overhead", ov);
        let mut p = JsonObj::new();
        p.insert("enabled", self.provision.enabled);
        p.insert("threshold", self.provision.threshold);
        p.insert("predictive", self.provision.predictive);
        p.insert("initial_instances", self.provision.initial_instances);
        p.insert("max_instances", self.provision.max_instances);
        p.insert("cold_start", self.provision.cold_start);
        p.insert("cooldown", self.provision.cooldown);
        p.insert("scale_down_idle", self.provision.scale_down_idle);
        p.insert("min_instances", self.provision.min_instances);
        o.insert("provision", p);
        o.insert("predictor_replicas", self.predictor_replicas);
        o.insert("frontends", self.frontends);
        o.insert("sync_interval", self.sync_interval);
        o.insert("shard_policy", self.shard_policy.name());
        o.insert("sync_on_ack", self.sync_on_ack);
        o.insert("local_echo", self.local_echo);
        o.insert("faults", self.faults.to_json());
        o.insert("detect", self.detect.to_json());
        o.insert("obs", self.obs.to_json());
        o.insert("jobs", self.jobs);
        o.insert("shards", self.shards);
        o.insert("window", self.window);
        o.insert("exec_noise", self.exec_noise);
        o.insert("seed", self.seed);
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut c = ClusterConfig::default();
        if let Some(v) = j.opt("n_instances") {
            c.n_instances = v.as_usize()?;
        }
        if let Some(v) = j.opt("gpu") {
            let name = v.as_str()?;
            c.gpu = hw::gpu_by_name(name)
                .ok_or_else(|| anyhow!("unknown gpu '{name}'"))?;
        }
        if let Some(v) = j.opt("model") {
            let name = v.as_str()?;
            c.model = hw::model_by_name(name)
                .ok_or_else(|| anyhow!("unknown model '{name}'"))?;
        }
        if let Some(v) = j.opt("scheduler") {
            c.scheduler = SchedulerKind::parse(v.as_str()?)?;
        }
        if let Some(e) = j.opt("engine") {
            if let Some(v) = e.opt("policy") {
                c.engine.policy = LocalPolicy::parse(v.as_str()?)?;
            }
            if let Some(v) = e.opt("max_batch_size") {
                c.engine.max_batch_size = v.as_usize()? as u32;
            }
            if let Some(v) = e.opt("chunk_size") {
                c.engine.chunk_size = v.as_usize()? as u32;
            }
            if let Some(v) = e.opt("block_size") {
                c.engine.block_size = v.as_usize()? as u32;
            }
            if let Some(v) = e.opt("num_blocks") {
                c.engine.num_blocks = Some(v.as_usize()? as u32);
            }
            if let Some(v) = e.opt("watermark") {
                c.engine.watermark = v.as_f64()?;
            }
            if let Some(v) = e.opt("max_model_len") {
                c.engine.max_model_len = v.as_usize()? as u32;
            }
        }
        if let Some(ov) = j.opt("overhead") {
            if let Some(v) = ov.opt("heuristic_base") {
                c.overhead.heuristic_base = v.as_f64()?;
            }
            if let Some(v) = ov.opt("predict_base") {
                c.overhead.predict_base = v.as_f64()?;
            }
            if let Some(v) = ov.opt("predict_per_step") {
                c.overhead.predict_per_step = v.as_f64()?;
            }
            if let Some(v) = ov.opt("sync_ack_cost") {
                c.overhead.sync_ack_cost = v.as_f64()?;
            }
        }
        if let Some(p) = j.opt("provision") {
            if let Some(v) = p.opt("enabled") {
                c.provision.enabled = v.as_bool()?;
            }
            if let Some(v) = p.opt("threshold") {
                c.provision.threshold = v.as_f64()?;
            }
            if let Some(v) = p.opt("predictive") {
                c.provision.predictive = v.as_bool()?;
            }
            if let Some(v) = p.opt("initial_instances") {
                c.provision.initial_instances = v.as_usize()?;
            }
            if let Some(v) = p.opt("max_instances") {
                c.provision.max_instances = v.as_usize()?;
            }
            if let Some(v) = p.opt("cold_start") {
                c.provision.cold_start = v.as_f64()?;
            }
            if let Some(v) = p.opt("cooldown") {
                c.provision.cooldown = v.as_f64()?;
            }
            if let Some(v) = p.opt("scale_down_idle") {
                c.provision.scale_down_idle = v.as_f64()?;
            }
            if let Some(v) = p.opt("min_instances") {
                c.provision.min_instances = v.as_usize()?;
            }
        }
        if let Some(v) = j.opt("predictor_replicas") {
            c.predictor_replicas = v.as_usize()?;
        }
        if let Some(v) = j.opt("frontends") {
            c.frontends = v.as_usize()?;
        }
        if let Some(v) = j.opt("sync_interval") {
            c.sync_interval = v.as_f64()?;
        }
        if let Some(v) = j.opt("shard_policy") {
            c.shard_policy = ShardPolicy::parse(v.as_str()?)?;
        }
        if let Some(v) = j.opt("sync_on_ack") {
            c.sync_on_ack = v.as_bool()?;
        }
        if let Some(v) = j.opt("local_echo") {
            c.local_echo = v.as_bool()?;
        }
        if let Some(f) = j.opt("faults") {
            c.faults = FaultConfig::from_json(f)?;
        }
        if let Some(d) = j.opt("detect") {
            c.detect = DetectConfig::from_json(d)?;
        }
        if let Some(d) = j.opt("obs") {
            c.obs = ObsConfig::from_json(d)?;
        }
        if let Some(v) = j.opt("jobs") {
            c.jobs = v.as_usize()?;
        }
        if let Some(v) = j.opt("shards") {
            c.shards = v.as_usize()?;
        }
        if let Some(v) = j.opt("window") {
            c.window = v.as_f64()?;
        }
        if let Some(v) = j.opt("exec_noise") {
            c.exec_noise = v.as_f64()?;
        }
        if let Some(v) = j.opt("seed") {
            c.seed = v.as_usize()? as u64;
        }
        c.validate()?;
        Ok(c)
    }

    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        Self::from_json(&Json::parse(&text)?)
    }
}

/// Workload selection for an experiment run.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadKind {
    /// Synthetic ShareGPT-like lengths (pure Rust generator).
    ShareGpt,
    /// Corpus-backed: real prompt text from artifacts/sharegpt_synth.jsonl.
    Corpus { path: String },
    /// BurstGPT-like bursty arrivals, shorter responses, lengths only.
    BurstGpt,
}

#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub kind: WorkloadKind,
    /// Mean external arrival rate, queries per second.
    pub qps: f64,
    /// Number of requests to send.
    pub n_requests: usize,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            kind: WorkloadKind::ShareGpt,
            qps: 24.0,
            n_requests: 10_000,
            seed: 7,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_valid_and_matches_paper() {
        let c = ClusterConfig::default();
        c.validate().unwrap();
        assert_eq!(c.n_instances, 12);
        assert_eq!(c.engine.max_batch_size, 48);
        assert_eq!(c.engine.chunk_size, 512);
        assert_eq!(c.kv_blocks(), 1056);
    }

    #[test]
    fn json_roundtrip() {
        let mut c = ClusterConfig::default();
        c.scheduler = SchedulerKind::LlumnixMinus;
        c.engine.max_batch_size = 24;
        c.provision.enabled = true;
        c.provision.predictive = false;
        c.provision.scale_down_idle = 12.0;
        c.provision.min_instances = 2;
        c.jobs = 4;
        c.shards = 3;
        c.window = 0.5;
        c.frontends = 3;
        c.sync_interval = 2.5;
        c.shard_policy = ShardPolicy::Hash;
        c.sync_on_ack = true;
        c.local_echo = true;
        c.overhead.sync_ack_cost = 0.005;
        c.faults.instance_mttf = 40.0;
        c.faults.frontend_mttf = 90.0;
        c.faults.frontend_mttr = 20.0;
        c.faults.prewarm = true;
        c.faults.slowdown_mttf = 50.0;
        c.faults.slowdown_duration = 12.0;
        c.faults.slowdown_factor = 4.0;
        c.faults.seed = 99;
        c.detect.enabled = true;
        c.detect.trip = 3.0;
        c.detect.min_samples = 5;
        c.obs.ring_capacity = 4096;
        c.obs.trace = TraceLevel::Decisions;
        c.obs.metrics = true;
        let j = c.to_json();
        let c2 = ClusterConfig::from_json(&j).unwrap();
        assert_eq!(c2.scheduler, SchedulerKind::LlumnixMinus);
        assert_eq!(c2.engine.max_batch_size, 24);
        assert!(c2.provision.enabled && !c2.provision.predictive);
        assert_eq!(c2.n_instances, c.n_instances);
        assert_eq!(c2.jobs, 4);
        assert_eq!(c2.shards, 3);
        assert!((c2.window - 0.5).abs() < 1e-12);
        assert_eq!(c2.frontends, 3);
        assert!((c2.sync_interval - 2.5).abs() < 1e-12);
        assert_eq!(c2.shard_policy, ShardPolicy::Hash);
        assert!(c2.sync_on_ack);
        assert!(c2.local_echo);
        assert!((c2.overhead.sync_ack_cost - 0.005).abs() < 1e-12);
        assert!((c2.faults.instance_mttf - 40.0).abs() < 1e-12);
        assert!((c2.faults.frontend_mttf - 90.0).abs() < 1e-12);
        assert!((c2.faults.frontend_mttr - 20.0).abs() < 1e-12);
        assert!(c2.faults.prewarm);
        assert!((c2.provision.scale_down_idle - 12.0).abs() < 1e-12);
        assert_eq!(c2.provision.min_instances, 2);
        assert!((c2.faults.slowdown_mttf - 50.0).abs() < 1e-12);
        assert!((c2.faults.slowdown_duration - 12.0).abs() < 1e-12);
        assert!((c2.faults.slowdown_factor - 4.0).abs() < 1e-12);
        assert!(c2.detect.enabled);
        assert!((c2.detect.trip - 3.0).abs() < 1e-12);
        assert_eq!(c2.detect.min_samples, 5);
        assert_eq!(c2.faults.seed, 99);
        assert!(c2.faults.enabled());
        assert_eq!(c2.obs.ring_capacity, 4096);
        assert_eq!(c2.obs.trace, TraceLevel::Decisions);
        assert!(c2.obs.metrics);
        assert!(c2.obs.any_enabled());
        assert!(!ObsConfig::default().any_enabled(),
                "obs must default to fully inert");
    }

    #[test]
    fn detect_and_slowdown_validation() {
        // Slowdowns alone make the fault subsystem non-inert.
        let mut f = FaultConfig::default();
        f.slowdown_mttf = 30.0;
        assert!(f.enabled());
        f.validate().unwrap();

        let mut c = ClusterConfig::default();
        c.faults.slowdown_factor = 0.5;
        assert!(c.validate().is_err(), "factor < 1 is a speedup, not a fault");

        let mut c = ClusterConfig::default();
        c.faults.slowdown_duration = 0.0;
        assert!(c.validate().is_err());

        let mut c = ClusterConfig::default();
        c.detect.alpha = 0.0;
        assert!(c.validate().is_err());

        let mut c = ClusterConfig::default();
        c.detect.clear = 5.0; // above trip
        assert!(c.validate().is_err());

        let mut c = ClusterConfig::default();
        c.detect.min_samples = 0;
        assert!(c.validate().is_err());

        let mut c = ClusterConfig::default();
        c.detect.restore_after = -1.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn fault_config_defaults_inert_and_validated() {
        let f = FaultConfig::default();
        assert!(!f.enabled());
        f.validate().unwrap();

        let mut c = ClusterConfig::default();
        c.faults.instance_mttr = 0.0;
        assert!(c.validate().is_err());

        let mut c = ClusterConfig::default();
        c.faults.instance_mttf = -1.0;
        assert!(c.validate().is_err());

        let mut c = ClusterConfig::default();
        c.faults.report_window = f64::INFINITY;
        assert!(c.validate().is_err());

        let mut c = ClusterConfig::default();
        c.faults.frontend_mttr = -2.0;
        assert!(c.validate().is_err());

        let mut c = ClusterConfig::default();
        c.provision.scale_down_idle = -1.0;
        assert!(c.validate().is_err());

        let mut c = ClusterConfig::default();
        c.provision.enabled = true;
        c.provision.scale_down_idle = 5.0;
        c.provision.min_instances = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = ClusterConfig::default();
        c.n_instances = 0;
        assert!(c.validate().is_err());

        let mut c = ClusterConfig::default();
        c.engine.num_blocks = Some(2);
        assert!(c.validate().is_err());

        let mut c = ClusterConfig::default();
        c.provision.enabled = true;
        c.provision.initial_instances = 12;
        c.provision.max_instances = 6;
        assert!(c.validate().is_err());

        let mut c = ClusterConfig::default();
        c.jobs = 0;
        assert!(c.validate().is_err());

        let mut c = ClusterConfig::default();
        c.shards = 0;
        assert!(c.validate().is_err());

        let mut c = ClusterConfig::default();
        c.window = -0.5;
        assert!(c.validate().is_err());

        let mut c = ClusterConfig::default();
        c.window = f64::NAN;
        assert!(c.validate().is_err());

        let mut c = ClusterConfig::default();
        c.frontends = 0;
        assert!(c.validate().is_err());

        let mut c = ClusterConfig::default();
        c.sync_interval = -1.0;
        assert!(c.validate().is_err());

        let mut c = ClusterConfig::default();
        c.sync_interval = f64::INFINITY;
        assert!(c.validate().is_err());
    }

    #[test]
    fn scheduler_parse_names() {
        for k in SchedulerKind::ALL {
            assert_eq!(SchedulerKind::parse(k.name()).unwrap(), k);
        }
        assert!(SchedulerKind::parse("magic").is_err());
    }

    #[test]
    fn shard_policy_parse_names() {
        for p in [ShardPolicy::RoundRobin, ShardPolicy::Hash,
                  ShardPolicy::Poisson] {
            assert_eq!(ShardPolicy::parse(p.name()).unwrap(), p);
        }
        assert_eq!(ShardPolicy::parse("random").unwrap(), ShardPolicy::Poisson);
        assert!(ShardPolicy::parse("sticky").is_err());
    }

    #[test]
    fn predictive_flags() {
        assert!(SchedulerKind::Block.is_predictive());
        assert!(SchedulerKind::BlockStar.uses_estimates());
        assert!(!SchedulerKind::Block.uses_estimates());
        assert!(!SchedulerKind::LlumnixMinus.is_predictive());
    }
}
