//! Auto-provisioning (§6.5): grow the cluster when latency crosses a
//! threshold.
//!
//! Two strategies from the paper:
//!
//! * **preempt** — trigger on *predicted* latency at dispatch time.  The
//!   Predictor sees the backlog forming before any request actually
//!   suffers, so instances come up earlier and fewer are needed.
//! * **relief** — trigger on *actual* (observed) latency of completed
//!   requests.  By the time a 70-second latency is observed, the backlog
//!   is deep; newly added hosts cannot relieve queued requests (cold-start
//!   asymmetry, §3), so provisioning cascades and over-shoots.
//!
//! The provisioner owns the active-instance set; a provisioned instance
//! becomes schedulable after `cold_start` seconds (model load).

use crate::config::ProvisionConfig;

/// A provisioning event (for the Figure-8 timeline).
#[derive(Debug, Clone, PartialEq)]
pub struct ProvisionEvent {
    pub time: f64,
    /// Instance index activated (ready at `time + cold_start`).
    pub instance: usize,
    /// The latency observation that triggered it.
    pub trigger_latency: f64,
}

#[derive(Debug)]
pub struct AutoProvisioner {
    cfg: ProvisionConfig,
    /// Per-instance active flag (ready to serve).
    active: Vec<bool>,
    /// Instances booting: (ready_time, index).
    pending: Vec<(f64, usize)>,
    /// Instances killed by fault injection: excluded from provisioning
    /// triggers until their `InstanceRejoin` clears the flag.  Failure
    /// and elastic scale-up share the pending → `activate_ready`
    /// lifecycle — a rejoining host is just a provisioned host whose
    /// cold start was scheduled by a fault plan instead of a latency
    /// trigger.
    failed: Vec<bool>,
    last_trigger: f64,
    pub events: Vec<ProvisionEvent>,
}

impl AutoProvisioner {
    pub fn new(cfg: ProvisionConfig, total_instances: usize) -> Self {
        assert!(cfg.max_instances <= total_instances);
        let mut active = vec![false; total_instances];
        for a in active.iter_mut().take(cfg.initial_instances) {
            *a = true;
        }
        AutoProvisioner {
            cfg,
            active,
            pending: Vec::new(),
            failed: vec![false; total_instances],
            last_trigger: f64::NEG_INFINITY,
            events: Vec::new(),
        }
    }

    /// Static cluster helper: everything active, no triggers.
    pub fn static_cluster(n: usize) -> Self {
        AutoProvisioner {
            cfg: ProvisionConfig { enabled: false, ..ProvisionConfig::default() },
            active: vec![true; n],
            pending: Vec::new(),
            failed: vec![false; n],
            last_trigger: f64::NEG_INFINITY,
            events: Vec::new(),
        }
    }

    pub fn active(&self) -> &[bool] {
        &self.active
    }

    /// Is instance `i` currently failed (fault-injected down, not yet
    /// rejoined)?  The provisioner is the single owner of per-instance
    /// lifecycle state — active, pending, failed.
    pub fn is_failed(&self, i: usize) -> bool {
        self.failed[i]
    }

    pub fn active_count(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    /// Observation from the dispatch path (predicted latency) — drives the
    /// `preempt` strategy.
    pub fn observe_predicted(&mut self, now: f64, predicted: f64) -> Option<f64> {
        if self.cfg.enabled && self.cfg.predictive {
            self.maybe_trigger(now, predicted)
        } else {
            None
        }
    }

    /// Observation from the completion path (actual e2e latency) — drives
    /// the `relief` strategy.
    pub fn observe_actual(&mut self, now: f64, actual: f64) -> Option<f64> {
        if self.cfg.enabled && !self.cfg.predictive {
            self.maybe_trigger(now, actual)
        } else {
            None
        }
    }

    /// Returns the ready time of a newly provisioned instance, if
    /// triggered.
    fn maybe_trigger(&mut self, now: f64, latency: f64) -> Option<f64> {
        if latency < self.cfg.threshold {
            return None;
        }
        if now - self.last_trigger < self.cfg.cooldown {
            return None;
        }
        let provisioned =
            self.active_count() + self.pending.len();
        if provisioned >= self.cfg.max_instances {
            return None;
        }
        // Next inactive, not-pending, not-failed instance index (a
        // failed host cannot be provisioned back — it rejoins through
        // its fault plan's `InstanceRejoin`).
        let idx = (0..self.active.len()).find(|&i| {
            !self.active[i]
                && !self.failed[i]
                && !self.pending.iter().any(|&(_, p)| p == i)
        })?;
        let ready = now + self.cfg.cold_start;
        self.pending.push((ready, idx));
        self.last_trigger = now;
        self.events.push(ProvisionEvent {
            time: now,
            instance: idx,
            trigger_latency: latency,
        });
        Some(ready)
    }

    /// Fault injection: instance `i` is gone.  Deactivates it, cancels
    /// any in-progress cold start, and removes it from the provisioning
    /// candidate pool until it rejoins.
    pub fn fail(&mut self, i: usize) {
        self.active[i] = false;
        self.failed[i] = true;
        self.pending.retain(|&(_, p)| p != i);
    }

    /// Fault injection: failed instance `i` starts rejoining at `now`.
    /// Flows through the same cold-start lifecycle as elastic scale-up
    /// (pending → [`Self::activate_ready`]); returns the ready time, or
    /// `None` when the instance is not actually down (never failed,
    /// already active, or mid-cold-start — scripted plans may request
    /// impossible rejoins).
    pub fn schedule_rejoin(&mut self, i: usize, now: f64,
                           cold_start: f64) -> Option<f64> {
        if !self.failed[i]
            || self.active[i]
            || self.pending.iter().any(|&(_, p)| p == i)
        {
            return None;
        }
        self.failed[i] = false;
        let ready = now + cold_start;
        self.pending.push((ready, i));
        Some(ready)
    }

    /// Activate instances whose cold start has elapsed.  Returns the
    /// indices that just became ready.
    pub fn activate_ready(&mut self, now: f64) -> Vec<usize> {
        let mut ready = Vec::new();
        self.pending.retain(|&(t, idx)| {
            if t <= now + 1e-12 {
                ready.push(idx);
                false
            } else {
                true
            }
        });
        for &i in &ready {
            self.active[i] = true;
        }
        ready
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(predictive: bool) -> ProvisionConfig {
        ProvisionConfig {
            enabled: true,
            threshold: 70.0,
            predictive,
            initial_instances: 6,
            max_instances: 10,
            cold_start: 40.0,
            cooldown: 15.0,
        }
    }

    #[test]
    fn initial_active_set() {
        let p = AutoProvisioner::new(cfg(true), 12);
        assert_eq!(p.active_count(), 6);
        assert!(p.active()[..6].iter().all(|&a| a));
        assert!(!p.active()[6]);
    }

    #[test]
    fn preempt_triggers_on_predicted_only() {
        let mut p = AutoProvisioner::new(cfg(true), 12);
        assert!(p.observe_actual(0.0, 100.0).is_none(), "relief path inert");
        let ready = p.observe_predicted(10.0, 80.0).unwrap();
        assert!((ready - 50.0).abs() < 1e-9);
        assert_eq!(p.active_count(), 6, "not active until cold start elapses");
        assert!(p.activate_ready(49.0).is_empty());
        assert_eq!(p.activate_ready(50.0), vec![6]);
        assert_eq!(p.active_count(), 7);
    }

    #[test]
    fn relief_triggers_on_actual_only() {
        let mut p = AutoProvisioner::new(cfg(false), 12);
        assert!(p.observe_predicted(0.0, 100.0).is_none());
        assert!(p.observe_actual(0.0, 71.0).is_some());
    }

    #[test]
    fn below_threshold_no_trigger() {
        let mut p = AutoProvisioner::new(cfg(true), 12);
        assert!(p.observe_predicted(0.0, 69.9).is_none());
        assert!(p.events.is_empty());
    }

    #[test]
    fn cooldown_spaces_triggers() {
        let mut p = AutoProvisioner::new(cfg(true), 12);
        assert!(p.observe_predicted(0.0, 90.0).is_some());
        assert!(p.observe_predicted(5.0, 90.0).is_none(), "inside cooldown");
        assert!(p.observe_predicted(15.0, 90.0).is_some());
        assert_eq!(p.events.len(), 2);
    }

    #[test]
    fn capped_at_max_instances() {
        let mut p = AutoProvisioner::new(cfg(true), 12);
        let mut t = 0.0;
        for _ in 0..20 {
            p.observe_predicted(t, 90.0);
            t += 20.0;
            p.activate_ready(t);
        }
        assert_eq!(p.active_count(), 10, "max_instances is the cap");
    }

    #[test]
    fn fail_and_rejoin_share_the_cold_start_lifecycle() {
        let mut p = AutoProvisioner::static_cluster(4);
        p.fail(2);
        assert_eq!(p.active_count(), 3);
        assert!(!p.active()[2]);

        // Rejoin goes through pending → activate_ready, like scale-up.
        let ready = p.schedule_rejoin(2, 10.0, 5.0).unwrap();
        assert!((ready - 15.0).abs() < 1e-12);
        assert_eq!(p.active_count(), 3, "cold start not elapsed");
        assert_eq!(p.activate_ready(15.0), vec![2]);
        assert_eq!(p.active_count(), 4);

        // Double rejoin / rejoin of a healthy instance are no-ops.
        assert!(p.schedule_rejoin(2, 20.0, 5.0).is_none());
        assert!(p.schedule_rejoin(0, 20.0, 5.0).is_none());
    }

    #[test]
    fn failed_instances_are_not_provisioning_candidates() {
        let mut p = AutoProvisioner::new(cfg(true), 12);
        // Kill the first backup slot; the latency trigger must skip it.
        p.fail(6);
        let ready = p.observe_predicted(0.0, 90.0).unwrap();
        p.activate_ready(ready);
        assert!(!p.active()[6], "failed host must not be re-provisioned");
        assert!(p.active()[7], "trigger skipped to the next backup");
    }

    #[test]
    fn fail_cancels_pending_cold_start() {
        let mut p = AutoProvisioner::new(cfg(true), 12);
        p.observe_predicted(0.0, 90.0).unwrap();
        p.fail(6);
        assert!(p.activate_ready(100.0).is_empty(),
                "cold start cancelled by the failure");
        assert_eq!(p.active_count(), 6, "the booting host never arrived");
    }

    #[test]
    fn static_cluster_never_triggers() {
        let mut p = AutoProvisioner::static_cluster(10);
        assert_eq!(p.active_count(), 10);
        assert!(p.observe_actual(0.0, 1000.0).is_none());
        assert!(p.observe_predicted(0.0, 1000.0).is_none());
    }
}
