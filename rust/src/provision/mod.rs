//! Auto-provisioning (§6.5): grow the cluster when latency crosses a
//! threshold.
//!
//! Two strategies from the paper:
//!
//! * **preempt** — trigger on *predicted* latency at dispatch time.  The
//!   Predictor sees the backlog forming before any request actually
//!   suffers, so instances come up earlier and fewer are needed.
//! * **relief** — trigger on *actual* (observed) latency of completed
//!   requests.  By the time a 70-second latency is observed, the backlog
//!   is deep; newly added hosts cannot relieve queued requests (cold-start
//!   asymmetry, §3), so provisioning cascades and over-shoots.
//!
//! The provisioner drives the shared [`crate::elastic::ActiveSet`]
//! lifecycle; a provisioned instance becomes schedulable after
//! `cold_start` seconds (model load).  Scale-down (drain + retire),
//! failure, pre-warming, and rejoin all flow through the same per-slot
//! state machine — see [`crate::elastic`].
//!
//! Sharded event loop: both observers only need barrier-consistent
//! state.  Preemptive observations run during serial phase-A dispatch
//! handling; relief observations, cold-start triggers and the idle
//! scale-down probes replay inside the window barrier's buffered
//! effects (`cluster::sharded`), in exact serial order with
//! finish-time timestamps — so `provision.enabled` runs the windowed
//! fast path and stays on the byte-parity surface.

use crate::config::ProvisionConfig;
use crate::elastic::ActiveSet;

/// A provisioning event (for the Figure-8 timeline).
#[derive(Debug, Clone, PartialEq)]
pub struct ProvisionEvent {
    pub time: f64,
    /// Instance index activated (ready at `time + cold_start`).
    pub instance: usize,
    /// The latency observation that triggered it.
    pub trigger_latency: f64,
}

#[derive(Debug)]
pub struct AutoProvisioner {
    cfg: ProvisionConfig,
    /// The shared per-slot lifecycle (single owner in simulator runs).
    set: ActiveSet,
    last_trigger: f64,
    pub events: Vec<ProvisionEvent>,
}

impl AutoProvisioner {
    pub fn new(cfg: ProvisionConfig, total_instances: usize) -> Self {
        assert!(cfg.max_instances <= total_instances);
        let set = ActiveSet::new(total_instances, cfg.initial_instances);
        AutoProvisioner {
            cfg,
            set,
            last_trigger: f64::NEG_INFINITY,
            events: Vec::new(),
        }
    }

    /// Static cluster helper: everything active, no triggers.
    pub fn static_cluster(n: usize) -> Self {
        AutoProvisioner {
            cfg: ProvisionConfig { enabled: false, ..ProvisionConfig::default() },
            set: ActiveSet::new(n, n),
            last_trigger: f64::NEG_INFINITY,
            events: Vec::new(),
        }
    }

    /// The dispatchable mask (Active slots only — Draining slots finish
    /// in-flight work but take no new dispatches).
    pub fn active(&self) -> &[bool] {
        self.set.mask()
    }

    /// The underlying lifecycle state machine (read side).
    pub fn lifecycle(&self) -> &ActiveSet {
        &self.set
    }

    /// The underlying lifecycle state machine (transition side) — the
    /// simulator's drain/retire path drives this directly.
    pub fn lifecycle_mut(&mut self) -> &mut ActiveSet {
        &mut self.set
    }

    /// Is instance `i` currently failed (fault-injected down, not yet
    /// rejoined)?
    pub fn is_failed(&self, i: usize) -> bool {
        self.set.is_failed(i)
    }

    /// May instance `i` still finish work (Active or Draining)?
    pub fn serving(&self, i: usize) -> bool {
        self.set.serving(i)
    }

    pub fn active_count(&self) -> usize {
        self.set.active_count()
    }

    /// Observation from the dispatch path (predicted latency) — drives the
    /// `preempt` strategy.
    pub fn observe_predicted(&mut self, now: f64, predicted: f64) -> Option<f64> {
        if self.cfg.enabled && self.cfg.predictive {
            self.maybe_trigger(now, predicted)
        } else {
            None
        }
    }

    /// Observation from the completion path (actual e2e latency) — drives
    /// the `relief` strategy.
    pub fn observe_actual(&mut self, now: f64, actual: f64) -> Option<f64> {
        if self.cfg.enabled && !self.cfg.predictive {
            self.maybe_trigger(now, actual)
        } else {
            None
        }
    }

    /// Returns the ready time of a newly provisioned instance, if
    /// triggered.
    fn maybe_trigger(&mut self, now: f64, latency: f64) -> Option<f64> {
        if latency < self.cfg.threshold {
            return None;
        }
        if now - self.last_trigger < self.cfg.cooldown {
            return None;
        }
        let provisioned = self.set.active_count() + self.set.pending_count();
        if provisioned >= self.cfg.max_instances {
            return None;
        }
        // First Backup/Retired slot (a failed host cannot be provisioned
        // back — it rejoins through its fault plan's `InstanceRejoin`).
        let idx = self.set.candidate()?;
        let ready = now + self.cfg.cold_start;
        self.set.begin_cold_start(idx, ready, now, "scale-up");
        self.last_trigger = now;
        self.events.push(ProvisionEvent {
            time: now,
            instance: idx,
            trigger_latency: latency,
        });
        Some(ready)
    }

    /// Fault injection: instance `i` is gone.  Deactivates it, cancels
    /// any in-progress cold start, and removes it from the provisioning
    /// candidate pool until it rejoins.
    pub fn fail(&mut self, i: usize, now: f64) {
        self.set.fail(i, now, "fail");
    }

    /// Fault injection: failed instance `i` starts rejoining at `now`.
    /// Flows through the same cold-start lifecycle as elastic scale-up
    /// (pending → [`Self::activate_ready`]); returns the ready time, or
    /// `None` when the instance is not actually down (never failed,
    /// already active, or mid-cold-start — scripted plans may request
    /// impossible rejoins, and a pre-warmed slot is already booting).
    pub fn schedule_rejoin(&mut self, i: usize, now: f64,
                           cold_start: f64) -> Option<f64> {
        if !self.set.is_failed(i) {
            return None;
        }
        let ready = now + cold_start;
        self.set.begin_cold_start(i, ready, now, "rejoin");
        Some(ready)
    }

    /// Failure-as-breach pre-warming: immediately cold-start the failed
    /// slot instead of waiting for its fault plan's rejoin (which then
    /// no-ops through [`Self::schedule_rejoin`]'s guard).  Returns the
    /// ready time, or `None` when `i` is not failed.
    pub fn prewarm(&mut self, i: usize, now: f64,
                   cold_start: f64) -> Option<f64> {
        if !self.set.is_failed(i) {
            return None;
        }
        let ready = now + cold_start;
        self.set.begin_cold_start(i, ready, now, "prewarm");
        Some(ready)
    }

    /// Activate instances whose cold start has elapsed.  Returns the
    /// indices that just became ready.
    pub fn activate_ready(&mut self, now: f64) -> Vec<usize> {
        self.set.activate_ready(now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(predictive: bool) -> ProvisionConfig {
        ProvisionConfig {
            enabled: true,
            threshold: 70.0,
            predictive,
            initial_instances: 6,
            max_instances: 10,
            cold_start: 40.0,
            cooldown: 15.0,
            ..ProvisionConfig::default()
        }
    }

    #[test]
    fn initial_active_set() {
        let p = AutoProvisioner::new(cfg(true), 12);
        assert_eq!(p.active_count(), 6);
        assert!(p.active()[..6].iter().all(|&a| a));
        assert!(!p.active()[6]);
    }

    #[test]
    fn preempt_triggers_on_predicted_only() {
        let mut p = AutoProvisioner::new(cfg(true), 12);
        assert!(p.observe_actual(0.0, 100.0).is_none(), "relief path inert");
        let ready = p.observe_predicted(10.0, 80.0).unwrap();
        assert!((ready - 50.0).abs() < 1e-9);
        assert_eq!(p.active_count(), 6, "not active until cold start elapses");
        assert!(p.activate_ready(49.0).is_empty());
        assert_eq!(p.activate_ready(50.0), vec![6]);
        assert_eq!(p.active_count(), 7);
    }

    #[test]
    fn relief_triggers_on_actual_only() {
        let mut p = AutoProvisioner::new(cfg(false), 12);
        assert!(p.observe_predicted(0.0, 100.0).is_none());
        assert!(p.observe_actual(0.0, 71.0).is_some());
    }

    #[test]
    fn below_threshold_no_trigger() {
        let mut p = AutoProvisioner::new(cfg(true), 12);
        assert!(p.observe_predicted(0.0, 69.9).is_none());
        assert!(p.events.is_empty());
    }

    #[test]
    fn cooldown_spaces_triggers() {
        let mut p = AutoProvisioner::new(cfg(true), 12);
        assert!(p.observe_predicted(0.0, 90.0).is_some());
        assert!(p.observe_predicted(5.0, 90.0).is_none(), "inside cooldown");
        assert!(p.observe_predicted(15.0, 90.0).is_some());
        assert_eq!(p.events.len(), 2);
    }

    #[test]
    fn capped_at_max_instances() {
        let mut p = AutoProvisioner::new(cfg(true), 12);
        let mut t = 0.0;
        for _ in 0..20 {
            p.observe_predicted(t, 90.0);
            t += 20.0;
            p.activate_ready(t);
        }
        assert_eq!(p.active_count(), 10, "max_instances is the cap");
    }

    #[test]
    fn fail_and_rejoin_share_the_cold_start_lifecycle() {
        let mut p = AutoProvisioner::static_cluster(4);
        p.fail(2, 0.0);
        assert_eq!(p.active_count(), 3);
        assert!(!p.active()[2]);

        // Rejoin goes through pending → activate_ready, like scale-up.
        let ready = p.schedule_rejoin(2, 10.0, 5.0).unwrap();
        assert!((ready - 15.0).abs() < 1e-12);
        assert_eq!(p.active_count(), 3, "cold start not elapsed");
        assert_eq!(p.activate_ready(15.0), vec![2]);
        assert_eq!(p.active_count(), 4);

        // Double rejoin / rejoin of a healthy instance are no-ops.
        assert!(p.schedule_rejoin(2, 20.0, 5.0).is_none());
        assert!(p.schedule_rejoin(0, 20.0, 5.0).is_none());
    }

    #[test]
    fn failed_instances_are_not_provisioning_candidates() {
        let mut p = AutoProvisioner::new(cfg(true), 12);
        // Kill the first backup slot; the latency trigger must skip it.
        p.fail(6, 0.0);
        let ready = p.observe_predicted(0.0, 90.0).unwrap();
        p.activate_ready(ready);
        assert!(!p.active()[6], "failed host must not be re-provisioned");
        assert!(p.active()[7], "trigger skipped to the next backup");
    }

    #[test]
    fn fail_cancels_pending_cold_start() {
        let mut p = AutoProvisioner::new(cfg(true), 12);
        p.observe_predicted(0.0, 90.0).unwrap();
        p.fail(6, 1.0);
        assert!(p.activate_ready(100.0).is_empty(),
                "cold start cancelled by the failure");
        assert_eq!(p.active_count(), 6, "the booting host never arrived");
    }

    #[test]
    fn static_cluster_never_triggers() {
        let mut p = AutoProvisioner::static_cluster(10);
        assert_eq!(p.active_count(), 10);
        assert!(p.observe_actual(0.0, 1000.0).is_none());
        assert!(p.observe_predicted(0.0, 1000.0).is_none());
    }

    #[test]
    fn prewarm_restarts_the_failed_slot_immediately() {
        let mut p = AutoProvisioner::static_cluster(4);
        p.fail(1, 10.0);
        let ready = p.prewarm(1, 10.0, 2.0).unwrap();
        assert!((ready - 12.0).abs() < 1e-12);
        // The fault plan's rejoin arrives later and must no-op: the slot
        // is already booting.
        assert!(p.schedule_rejoin(1, 20.0, 5.0).is_none());
        assert_eq!(p.activate_ready(12.0), vec![1]);
        assert_eq!(p.active_count(), 4);
        // Pre-warming a healthy slot is a no-op.
        assert!(p.prewarm(0, 30.0, 2.0).is_none());
    }

    #[test]
    fn drain_then_retire_returns_slot_to_candidate_pool() {
        let mut p = AutoProvisioner::new(cfg(true), 12);
        p.lifecycle_mut().begin_drain(2, 5.0, "scale-down");
        assert_eq!(p.active_count(), 5);
        assert!(p.serving(2), "draining slot still finishes work");
        assert!(!p.active()[2], "but takes no new dispatches");
        p.lifecycle_mut().retire(2, 6.0, "retire");
        assert!(!p.serving(2));
        // The latency trigger now prefers the retired slot (lowest
        // eligible index) over the untouched backups.
        let ready = p.observe_predicted(10.0, 90.0).unwrap();
        assert_eq!(p.activate_ready(ready), vec![2]);
        assert!(p.active()[2]);
    }
}
