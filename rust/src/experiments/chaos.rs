//! Chaos sweep — the recovery claim under measurement: how does each
//! dispatcher absorb component deaths, and does the stateless front-end
//! tier really have nothing to recover?
//!
//! Every (fault level × front-end count × scheduler) point runs the
//! same near-capacity workload with a randomized-but-seeded
//! [`crate::faults::FaultPlan`]: instances fail and rejoin on
//! MTTF/MTTR exponentials scaled to the workload span, and (at the
//! heavy level) front-ends crash permanently.  The `none` level is the
//! healthy baseline every faulty point is judged against.
//!
//! What the recovery telemetry should show:
//!
//! * **front-end crashes cost ~nothing** — `redispatched` stays 0 for
//!   crash faults; only the arrival re-shard (`redirected`) moves;
//! * **instance failures cost real work** — lost sequences re-dispatch
//!   through the survivors, visible as a disruption window and a
//!   goodput dip around each fault;
//! * **predictive re-dispatch places better** — Block re-predicts the
//!   bounced requests against the shrunken cluster, while the counter
//!   heuristics re-count blocks from (possibly stale) views;
//! * **pre-warming shrinks the disruption window** — every faulty point
//!   runs twice, rejoin-wait (the failed host comes back after MTTR)
//!   vs failure-as-breach pre-warm (`faults.prewarm`: the failure
//!   itself schedules a cold-start replacement), and the pre-warm run
//!   must show the smaller mean disruption window and goodput dip.
//!
//! Results land in `results/chaos.json` (`schema: "chaos/v1"`),
//! validated by the `chaos-smoke` CI job.

use anyhow::Result;

use crate::cluster::{run_experiment, SimOptions};
use crate::config::SchedulerKind;
use crate::experiments::{paper_cluster, parallel_map, sharegpt_workload,
                         ExpContext, Scale};
use crate::faults::RecoveryStats;
use crate::metrics::{render_table, RunSummary};
use crate::util::json::{Json, JsonObj};

/// Dispatchers compared: the predictive scheduler vs the two strongest
/// heuristic baselines (mirroring the staleness sweep).
const KINDS: [SchedulerKind; 3] = [
    SchedulerKind::Block,
    SchedulerKind::MinQpm,
    SchedulerKind::LlumnixMinus,
];

/// QPS of the sweep workload (same contended region as the staleness
/// sweep: ~80% of 12-instance capacity).
const SWEEP_QPS: f64 = 64.0;

/// Fault levels: (name, instance-MTTF multiple of the workload span,
/// front-end-MTTF multiple of the span; 0 = that fault class off).
/// At `heavy`, a 12-instance cluster expects ~6 instance failures per
/// run and each non-zero front-end crashes with probability ~0.49.
const LEVELS: [(&str, f64, f64); 3] = [
    ("none", 0.0, 0.0),
    ("light", 8.0, 0.0),
    ("heavy", 2.0, 1.4),
];

/// Front-end counts per scale.
fn frontend_points(scale: Scale) -> Vec<usize> {
    match scale {
        Scale::Quick => vec![1, 2, 4],
        Scale::Full => vec![1, 2, 4, 8],
    }
}

struct Point {
    frontends: usize,
    level: &'static str,
    kind: SchedulerKind,
    requests: usize,
    summary: RunSummary,
    recovery: RecoveryStats,
    instance_mttf: f64,
    frontend_mttf: f64,
    /// The same point re-run with failure-as-breach pre-warming
    /// (`faults.prewarm = true`, identical fault plan) — only for
    /// levels with instance faults.
    prewarm: Option<(RunSummary, RecoveryStats)>,
    /// Full run telemetry (`SimResult::telemetry_json` — events
    /// processed, sync stats, recovery, size timeline), captured before
    /// the result is dropped.
    telemetry: Json,
}

pub fn run(ctx: &ExpContext) -> Result<()> {
    // The smoke grid is CI-sized: one distributed shape, a healthy
    // point plus a deliberately dense fault level (every fault path
    // exercised with near-certainty), a few hundred requests per point
    // — a schema-complete chaos.json in seconds.
    let (fe_points, levels, n): (Vec<usize>, Vec<(&str, f64, f64)>, usize) =
        if ctx.smoke {
            (vec![2], vec![("none", 0.0, 0.0), ("heavy", 0.5, 1.0)], 300)
        } else {
            (frontend_points(ctx.scale), LEVELS.to_vec(),
             ctx.scale.requests_for(SWEEP_QPS))
        };
    let span = n as f64 / SWEEP_QPS;

    let mut grid = Vec::new();
    for &frontends in &fe_points {
        for &level in &levels {
            for kind in KINDS {
                grid.push((frontends, level, kind));
            }
        }
    }
    let points = parallel_map(
        ctx.jobs,
        &grid,
        |&(frontends, level, kind)| -> Result<Point> {
            let (name, inst_mult, fe_mult) = level;
            let mut cfg = paper_cluster(kind);
            cfg.frontends = frontends;
            cfg.sync_interval = if frontends == 1 { 0.0 } else { 1.0 };
            cfg.shard_policy = ctx.shard;
            // `--shards`: multi-frontend points are window-overlap
            // eligible (faults are barrier-class), so the sharded loop
            // is a pure wall-clock win; single-frontend points run
            // fresh views and fall back to the serialized path.
            cfg.shards = ctx.shards;
            cfg.faults.instance_mttf = inst_mult * span;
            cfg.faults.instance_mttr = span / 4.0;
            cfg.faults.frontend_mttf = fe_mult * span;
            // Crashed front-ends come back with a cold view after an
            // MTTR (the restart-with-empty-view path) at levels that
            // crash front-ends at all.
            cfg.faults.frontend_mttr =
                if fe_mult > 0.0 { span / 4.0 } else { 0.0 };
            cfg.faults.rejoin_cold_start = 2.0;
            cfg.faults.report_window = (span / 3.0).clamp(1.0, 15.0);
            cfg.faults.seed = ctx.seed ^ 0xC4A0;
            let workload = sharegpt_workload(SWEEP_QPS, n, ctx.seed);
            let opts = SimOptions { probes: false, ..SimOptions::default() };
            // Conservation law, checked on every run: what was not
            // served must be explicitly dropped.
            let conserve = |res: &crate::cluster::SimResult| {
                anyhow::ensure!(
                    res.metrics.len() as u64 + res.recovery.dropped
                        == n as u64,
                    "conservation violated: {} served + {} dropped != {n}",
                    res.metrics.len(), res.recovery.dropped,
                );
                Ok(())
            };
            let res = run_experiment(cfg.clone(), &workload, opts.clone())?;
            conserve(&res)?;
            // Pre-warm comparison: identical fault plan (same seed),
            // only the recovery policy differs — the failure itself
            // schedules a cold-start replacement instead of waiting
            // out the rejoin MTTR.
            let prewarm = if inst_mult > 0.0 {
                let mut pcfg = cfg;
                pcfg.faults.prewarm = true;
                let pres = run_experiment(pcfg, &workload, opts)?;
                conserve(&pres)?;
                Some((pres.metrics.summary(), pres.recovery))
            } else {
                None
            };
            Ok(Point {
                frontends,
                level: name,
                kind,
                requests: n,
                summary: res.metrics.summary(),
                telemetry: res.telemetry_json(),
                recovery: res.recovery,
                instance_mttf: inst_mult * span,
                frontend_mttf: fe_mult * span,
                prewarm,
            })
        },
    );

    let mut out = JsonObj::new();
    out.insert("schema", "chaos/v1");
    out.insert("qps", SWEEP_QPS);
    out.insert("requests_per_point", n);
    out.insert("shard_policy", ctx.shard.name());
    let mut pts = JsonObj::new();
    let mut rows = Vec::new();
    for point in points {
        let p = point?;
        let s = &p.summary;
        let r = &p.recovery;
        // Pre-warm vs rejoin-wait columns ("-" at fault-free points).
        let (pw_disrupt, pw_dip) = match &p.prewarm {
            Some((_, pr)) => (
                format!("{:.2}", pr.mean_disruption()),
                format!("{:.2}", pr.mean_goodput_dip()),
            ),
            None => ("-".to_string(), "-".to_string()),
        };
        rows.push(vec![
            format!("{}", p.frontends),
            p.level.to_string(),
            p.kind.name().to_string(),
            format!("{:.3}", s.p99_ttft),
            format!("{:.2}", s.p99_e2e),
            format!("{}", s.n),
            format!("{}", r.dropped),
            format!("{}", r.reports.len()),
            format!("{}", r.total_redispatched),
            format!("{}", r.total_redirected),
            format!("{:.2}", r.mean_disruption()),
            pw_disrupt,
            format!("{:.2}", r.mean_goodput_dip()),
            pw_dip,
            format!("{:.2}", r.worst_p99_after()),
        ]);
        let mut j = s.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("scheduler", p.kind.name());
            o.insert("frontends", p.frontends);
            o.insert("level", p.level);
            o.insert("requests", p.requests);
            o.insert("instance_mttf", p.instance_mttf);
            o.insert("frontend_mttf", p.frontend_mttf);
            o.insert("recovery", r.to_json());
            o.insert("telemetry", p.telemetry.clone());
            if let Some((ps, pr)) = &p.prewarm {
                let mut pw = match ps.to_json() {
                    Json::Obj(pw) => pw,
                    _ => unreachable!("summary serializes to an object"),
                };
                pw.insert("recovery", pr.to_json());
                o.insert("prewarm", Json::Obj(pw));
            }
        }
        pts.insert(
            format!("{}@fe{}/{}", p.kind.name(), p.frontends, p.level),
            j,
        );
    }
    out.insert("points", Json::Obj(pts));
    println!("Chaos sweep — fault level × front-ends at {SWEEP_QPS} QPS \
              ({n} requests/point, {:.0}s span; disrupt/dip columns \
              compare rejoin-wait vs pre-warm)", span);
    println!("{}", render_table(
        &["frontends", "faults", "scheduler", "p99 TTFT", "p99 e2e",
          "served", "drop", "n_flt", "redisp", "redir", "disrupt(s)",
          "pw_disrupt", "dip", "pw_dip", "p99@fault"],
        &rows));

    ctx.write_json("chaos", &Json::Obj(out))
}
