//! Experiment harness: one entry per paper table/figure (see DESIGN.md's
//! per-experiment index).  Each experiment prints the paper's rows/series
//! and writes machine-readable JSON under `results/`.
//!
//! QPS points and request counts are scaled by `Scale`: the paper's
//! 12-A30 testbed sweeps QPS 20-36 over 10k requests; `Scale::Quick`
//! shrinks counts for CI while preserving every qualitative shape.
//! (Our simulated cluster saturates near ~60 QPS rather than the paper's
//! 20-36 — see EXPERIMENTS.md §Calibration for the accounting.)
//!
//! Sweeps are embarrassingly parallel: every (scheduler × QPS) point is
//! an independent simulation with its own seed, so fig6/fig8/tab2 fan
//! points out over [`parallel_map`] with `ExpContext::jobs` workers
//! (`--jobs N` on the CLI).  Results are slotted back by input index and
//! each point's seed depends only on `ctx.seed`, so output is identical
//! for any job count — parallelism changes wall-clock, never numbers.

pub mod chaos;
pub mod fig5;
pub mod graychaos;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod staleness;
pub mod tab1;
pub mod tab2;

use anyhow::Result;

use crate::config::{ClusterConfig, SchedulerKind, ShardPolicy, WorkloadConfig,
                    WorkloadKind};
use crate::util::json::Json;

/// Experiment size.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// CI-sized: minutes of wall time, hundreds of requests per point.
    Quick,
    /// Paper-sized sweep (thousands of requests per point).
    Full,
}

impl Scale {
    pub fn parse(s: &str) -> Option<Scale> {
        match s {
            "quick" => Some(Scale::Quick),
            "full" => Some(Scale::Full),
            _ => None,
        }
    }

    pub fn requests(&self, full: usize) -> usize {
        match self {
            Scale::Quick => (full / 10).max(200),
            Scale::Full => full,
        }
    }

    /// Workload duration in virtual seconds.  Sizing runs by *duration*
    /// (n = qps * duration) rather than request count keeps high-QPS
    /// points long enough for queues to reach steady state — a fixed
    /// count at high QPS would end before saturation shows.
    pub fn duration(&self) -> f64 {
        match self {
            Scale::Quick => 45.0,
            Scale::Full => 180.0,
        }
    }

    /// Requests for a QPS point at this scale.
    pub fn requests_for(&self, qps: f64) -> usize {
        ((qps * self.duration()) as usize).max(200)
    }
}

/// Common experiment context.
#[derive(Debug, Clone)]
pub struct ExpContext {
    pub scale: Scale,
    pub out_dir: String,
    pub seed: u64,
    /// Worker threads for sweep points (`--jobs`; default: all cores).
    pub jobs: usize,
    /// Arrival sharding for the distributed-deployment sweeps
    /// (`--shard`; read by [`staleness`] and [`chaos`], ignored by the
    /// centralized paper experiments).
    pub shard: ShardPolicy,
    /// CI smoke mode (`--smoke`): shrink the sweep grid to a
    /// schema-complete minimum (read by [`chaos`] and [`graychaos`];
    /// other experiments ignore it — their CI sizing is
    /// `Scale::Quick`).
    pub smoke: bool,
    /// Event-loop shards per simulation (`--shards`; read by [`chaos`]
    /// and [`graychaos`], whose knob space is window-overlap eligible
    /// since the quantized-knob lifts).  Results are byte-identical to
    /// `shards = 1` — this is purely a wall-clock knob.
    pub shards: usize,
}

impl Default for ExpContext {
    fn default() -> Self {
        ExpContext {
            scale: Scale::Quick,
            out_dir: "results".into(),
            seed: 7,
            jobs: default_jobs(),
            shard: ShardPolicy::RoundRobin,
            smoke: false,
            shards: 1,
        }
    }
}

/// Default sweep parallelism: every core (sweep points are independent
/// single-threaded simulations).
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map_or(1, |n| n.get())
}

/// Ordered scoped-thread fan-out (shared with the Block scheduler's
/// prediction fan-out; implemented in [`crate::util::parallel`]).
pub use crate::util::parallel::parallel_map;

impl ExpContext {
    pub fn write_json(&self, name: &str, value: &Json) -> Result<()> {
        std::fs::create_dir_all(&self.out_dir)?;
        let path = format!("{}/{name}.json", self.out_dir);
        std::fs::write(&path, value.to_string_pretty())?;
        println!("[written {path}]");
        Ok(())
    }
}

/// Baseline 12-instance cluster of the paper's §6.1 setup.
pub fn paper_cluster(scheduler: SchedulerKind) -> ClusterConfig {
    ClusterConfig { scheduler, ..ClusterConfig::default() }
}

/// ShareGPT workload at a QPS point.
pub fn sharegpt_workload(qps: f64, n: usize, seed: u64) -> WorkloadConfig {
    WorkloadConfig { kind: WorkloadKind::ShareGpt, qps, n_requests: n, seed }
}

/// The QPS sweep of Figure 6 (paper: 20..36 on 12 instances).
/// Our simulated A30 cluster saturates around ~60 QPS at 12 instances
/// (see EXPERIMENTS.md §Calibration), so the sweep covers the same
/// *relative* region: from ~60% of capacity to just past it.
pub fn fig6_qps_points(scale: Scale) -> Vec<f64> {
    match scale {
        Scale::Quick => vec![52.0, 64.0, 72.0, 78.0],
        Scale::Full => vec![48.0, 56.0, 62.0, 66.0, 70.0, 74.0, 78.0, 82.0],
    }
}

/// Run a named experiment.
pub fn run(name: &str, ctx: &ExpContext) -> Result<()> {
    match name {
        "tab1" => tab1::run(ctx),
        "fig5" => fig5::run(ctx),
        "fig6" => fig6::run(ctx),
        "fig7" => fig7::run(ctx),
        "fig8" => fig8::run(ctx),
        "tab2" => tab2::run(ctx),
        "staleness" => staleness::run(ctx),
        "chaos" => chaos::run(ctx),
        "graychaos" => graychaos::run(ctx),
        "all" => {
            for n in ["tab1", "fig5", "fig6", "fig7", "fig8", "tab2",
                      "staleness", "chaos", "graychaos"] {
                println!("\n=============== {n} ===============");
                run(n, ctx)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown experiment '{other}' \
                                (tab1|fig5|fig6|fig7|fig8|tab2|staleness|\
                                 chaos|graychaos|all)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_parses() {
        assert_eq!(Scale::parse("quick"), Some(Scale::Quick));
        assert_eq!(Scale::parse("full"), Some(Scale::Full));
        assert_eq!(Scale::parse("x"), None);
        assert_eq!(Scale::Quick.requests(10_000), 1000);
        assert_eq!(Scale::Full.requests(10_000), 10_000);
    }

    #[test]
    fn unknown_experiment_errors() {
        assert!(run("nope", &ExpContext::default()).is_err());
    }
}
