//! Figure 5 — latency-prediction quality of the online simulator.
//!
//! Three panels, as in the paper:
//!
//! 1. **Error rate vs QPS** (chunked vs prioritized prefill): mean
//!    |predicted - actual| / actual over Block-scheduled requests.
//!    Chunked prefill should predict better (no stall bubbles).
//! 2. **Predicted-vs-actual scatter**: sampled requests' dispatch-time
//!    prediction against their realized latency.
//! 3. **Selected-instance rank**: for 1%-sampled arrivals (broadcast to
//!    all instances, random placement — the paper's §6.2.2 protocol), the
//!    rank of the min-predicted instance under a noise-perturbed
//!    counterfactual execution of every instance.  High mass at rank 1 =
//!    the predictor picks the actually-best instance.

use anyhow::Result;

use crate::cluster::{run_experiment, SimOptions};
use crate::config::{LocalPolicy, SchedulerKind};
use crate::core::batch::BatchPlan;
use crate::exec::roofline::RooflineModel;
use crate::exec::BatchCost;
use crate::experiments::{paper_cluster, sharegpt_workload, ExpContext, Scale};
use crate::metrics::render_table;
use crate::predictor::{Predictor, TrueLengths};
use crate::util::json::{Json, JsonObj};
use crate::util::rng::Rng;

/// Multiplicative-noise wrapper: the "actual execution" counterfactual.
/// (`Mutex` rather than `RefCell` because `BatchCost` is `Send + Sync`;
/// this wrapper is only ever driven by one simulation at a time.)
struct NoisyCost<'a> {
    inner: &'a RooflineModel,
    rng: std::sync::Mutex<Rng>,
    sigma: f64,
}

impl BatchCost for NoisyCost<'_> {
    fn batch_time(&self, plan: &BatchPlan) -> f64 {
        let z = self.rng.lock().unwrap().normal();
        self.inner.batch_time(plan) * (1.0 + self.sigma * z).max(0.2)
    }
}

pub fn run(ctx: &ExpContext) -> Result<()> {
    let qps_points = match ctx.scale {
        Scale::Quick => vec![52.0, 64.0, 72.0],
        Scale::Full => vec![48.0, 56.0, 62.0, 68.0, 72.0, 76.0],
    };

    // Panel 1: prediction error rate vs QPS, chunked vs prioritized.
    let mut rows = Vec::new();
    let mut out = JsonObj::new();
    for policy in [LocalPolicy::SarathiChunked, LocalPolicy::VllmPrefillPriority] {
        for &qps in &qps_points {
            let n = ctx.scale.requests_for(qps);
            let mut cfg = paper_cluster(SchedulerKind::Block);
            cfg.engine.policy = policy;
            let res = run_experiment(cfg, &sharegpt_workload(qps, n, ctx.seed),
                                     SimOptions { probes: false,
                                                  ..SimOptions::default() })?;
            let s = res.metrics.summary();
            let err = s.pred_error_rate.unwrap_or(f64::NAN);
            rows.push(vec![policy.name().into(), format!("{qps:.0}"),
                           format!("{:.1}%", err * 100.0)]);
            out.insert(format!("err_rate:{}@{qps}", policy.name()), err);
        }
    }
    println!("Figure 5 (top) — prediction error rate vs QPS:");
    println!("{}", render_table(&["local policy", "qps", "error rate"], &rows));

    // Panels 2+3: sampled broadcast under the random scheduler.
    let probe_qps = *qps_points.last().unwrap() * 0.85;
    let n = ctx.scale.requests_for(probe_qps);
    let cfg = paper_cluster(SchedulerKind::Random);
    let res = run_experiment(cfg.clone(),
                             &sharegpt_workload(probe_qps, n, ctx.seed),
                             SimOptions { probes: false, sample_prob: 0.02,
                                          ..SimOptions::default() })?;
    let cost = RooflineModel::from_profiles(&cfg.gpu, &cfg.model);
    let predictor = Predictor::new(cfg.engine.clone(), cfg.kv_blocks());
    let mut rank_hist = vec![0usize; cfg.n_instances];
    let mut scatter = Vec::new();
    for (si, s) in res.sampled.iter().enumerate() {
        // Predictions per instance.
        let preds: Vec<(usize, f64)> = s.statuses.iter()
            .map(|(i, st)| {
                (*i, predictor.predict(st, &s.request, &cost, &TrueLengths).e2e)
            })
            .collect();
        // Counterfactual "actual" with execution noise.
        let noisy = NoisyCost {
            inner: &cost,
            rng: std::sync::Mutex::new(Rng::new(ctx.seed ^ (si as u64) << 3)),
            sigma: cfg.exec_noise,
        };
        // Cache-bypassing predict: the memo cache is keyed only by batch
        // plan, so the clean predictions above would otherwise be
        // replayed verbatim and the "actual" execution would equal the
        // prediction exactly (rank 1 everywhere, by construction).
        let actuals: Vec<(usize, f64)> = s.statuses.iter()
            .map(|(i, st)| {
                (*i, predictor.predict_uncached(st, &s.request, &noisy,
                                                &TrueLengths).e2e)
            })
            .collect();
        let best_pred = preds.iter()
            .min_by(|a, b| a.1.total_cmp(&b.1)).unwrap().0;
        let mut order: Vec<usize> = (0..actuals.len()).collect();
        order.sort_by(|&a, &b| actuals[a].1.total_cmp(&actuals[b].1));
        let rank = order.iter()
            .position(|&k| actuals[k].0 == best_pred).unwrap();
        let idx = rank.min(rank_hist.len() - 1);
        rank_hist[idx] += 1;
        for ((i, p), (_, a)) in preds.iter().zip(&actuals) {
            let _ = i;
            scatter.push((*p, *a));
        }
    }
    let total: usize = rank_hist.iter().sum();
    println!("Figure 5 (bottom) — rank of min-predicted instance under \
              counterfactual execution ({total} sampled broadcasts at QPS \
              {probe_qps:.0}):");
    let rank_rows: Vec<Vec<String>> = rank_hist.iter().enumerate()
        .take(6)
        .map(|(r, &c)| vec![format!("{}", r + 1),
                            format!("{:.1}%", 100.0 * c as f64 / total.max(1) as f64)])
        .collect();
    println!("{}", render_table(&["rank", "fraction"], &rank_rows));

    out.insert("rank_hist", Json::Arr(
        rank_hist.iter().map(|&c| Json::Num(c as f64)).collect()));
    out.insert("scatter", Json::Arr(
        scatter.iter().take(2000)
            .map(|&(p, a)| Json::Arr(vec![p.into(), a.into()])).collect()));
    out.insert("probe_qps", probe_qps);
    ctx.write_json("fig5", &Json::Obj(out))
}
