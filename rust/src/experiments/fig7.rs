//! Figure 7 — GPU memory utilization: average and variance of free KV
//! blocks across instances (probed before each dispatch) and cumulative
//! preemption counts, under increasing QPS.
//!
//! Expected shape: Block keeps cross-instance variance lowest and
//! preempts least; heuristics show high variance (imbalance) and
//! preemption storms once QPS passes capacity.

use anyhow::Result;

use crate::cluster::{run_experiment, SimOptions};
use crate::config::SchedulerKind;
use crate::experiments::{fig6_qps_points, paper_cluster, sharegpt_workload,
                         ExpContext};
use crate::metrics::render_table;
use crate::util::json::{Json, JsonObj};
use crate::util::stats::{gaussian_smooth, mean, variance};

pub fn run(ctx: &ExpContext) -> Result<()> {
    let qps_points = fig6_qps_points(ctx.scale);
    let schedulers = [SchedulerKind::Random, SchedulerKind::InfaasPp,
                      SchedulerKind::LlumnixMinus, SchedulerKind::Block];

    let mut out = JsonObj::new();
    let mut rows = Vec::new();
    for &qps in &qps_points {
        let n = ctx.scale.requests_for(qps);
        for kind in schedulers {
            let mut cfg = paper_cluster(kind);
            // Memory-pressure emulation: our synthetic ShareGPT responses
            // are lighter than the authors' sample, so the full 1056-block
            // A30 budget never binds before compute does.  Shrinking the
            // KV pool reproduces the paper's §6.4 regime where preemption
            // storms appear once QPS passes capacity (documented in
            // EXPERIMENTS.md).
            cfg.engine.num_blocks = Some(640);
            let res = run_experiment(
                cfg,
                &sharegpt_workload(qps, n, ctx.seed),
                SimOptions { probes: true, ..SimOptions::default() },
            )?;
            // Per-probe free-block average and cross-instance variance.
            let avg_series: Vec<f64> = res.probes.iter()
                .map(|p| mean(&p.free_blocks.iter().map(|&b| b as f64)
                              .collect::<Vec<_>>()))
                .collect();
            let var_series: Vec<f64> = res.probes.iter()
                .map(|p| variance(&p.free_blocks.iter().map(|&b| b as f64)
                                  .collect::<Vec<_>>()))
                .collect();
            let preempt_series: Vec<f64> = res.probes.iter()
                .map(|p| p.cum_preemptions as f64)
                .collect();
            let total_preempts = preempt_series.last().copied().unwrap_or(0.0);
            rows.push(vec![
                format!("{qps:.0}"),
                kind.name().to_string(),
                format!("{:.0}", mean(&avg_series)),
                format!("{:.0}", mean(&var_series)),
                format!("{total_preempts:.0}"),
            ]);
            // Paper smooths the plotted series with a Gaussian filter.
            let mut j = JsonObj::new();
            let smooth = |v: &[f64]| {
                Json::Arr(gaussian_smooth(v, 25.0).iter().step_by(10)
                          .map(|&x| Json::Num(x)).collect())
            };
            j.insert("avg_free_blocks", smooth(&avg_series));
            j.insert("var_free_blocks", smooth(&var_series));
            j.insert("cum_preemptions", smooth(&preempt_series));
            out.insert(format!("{}@{qps}", kind.name()), j);
        }
    }
    println!("Figure 7 — memory balance + preemptions \
              ({}s of load per point)", ctx.scale.duration());
    println!("{}", render_table(
        &["qps", "scheduler", "mean free blocks", "mean variance",
          "total preemptions"],
        &rows));
    ctx.write_json("fig7", &Json::Obj(out))
}
