//! Staleness sweep — the distributed-deployment claim the paper leaves
//! untested: how does each dispatcher degrade as the number of stateless
//! scheduler front-ends and the view-sync interval grow?
//!
//! Every (front-ends × sync-interval × scheduler) point runs the same
//! near-capacity workload.  `frontends = 1, sync_interval = 0` is the
//! centralized baseline every other point is judged against.  The
//! expectation from the paper's design argument: Block's predictive
//! dispatch — which ranks instances by *simulated futures* of their
//! snapshots — degrades gracefully as snapshots age, while load-counter
//! heuristics (MinQPM's per-gateway dispatch history, Llumnix-'s memory
//! probe) lose exactly the signal they rank by and herd.
//!
//! Reported per point: p99 TTFT, mean/p99 e2e, preemptions, and the
//! gateway skew — the coefficient of variation of per-front-end dispatch
//! counts (0 = perfectly even; grows with hash/Poisson sharding).
//! Results land in `results/staleness.json`.
//!
//! Every stale point (`sync_interval > 0`) runs twice: periodic pulls
//! only, and with ack-piggybacked per-dispatch refreshes
//! (`sync_on_ack`).  The ack variant is *charged* — each dispatch pays
//! [`crate::config::OverheadConfig::sync_ack_cost`] for the status
//! serialization — so comparing the two rows at each interval exposes
//! the real break-even: below it, paying per-dispatch serialization
//! beats going stale; above it, the periodic pull is the better deal.

use anyhow::Result;

use crate::cluster::{run_experiment, SimOptions};
use crate::config::SchedulerKind;
use crate::experiments::{paper_cluster, parallel_map, sharegpt_workload,
                         ExpContext, Scale};
use crate::metrics::{render_table, RunSummary};
use crate::util::json::{Json, JsonObj};

/// Dispatchers compared: the predictive scheduler vs the two strongest
/// heuristic baselines (per Figure 6).
const KINDS: [SchedulerKind; 3] = [
    SchedulerKind::Block,
    SchedulerKind::MinQpm,
    SchedulerKind::LlumnixMinus,
];

/// QPS of the sweep workload: inside the contended region of the
/// fig6 sweep (~80% of the 12-instance capacity), where dispatch
/// quality is visible but the centralized baseline is not yet saturated.
const SWEEP_QPS: f64 = 64.0;

/// Front-end counts × sync intervals (seconds) per scale.
fn sweep_axes(scale: Scale) -> (Vec<usize>, Vec<f64>) {
    match scale {
        Scale::Quick => (vec![1, 2, 4], vec![0.0, 1.0, 4.0]),
        Scale::Full => (vec![1, 2, 4, 8], vec![0.0, 0.5, 2.0, 8.0]),
    }
}

struct Point {
    frontends: usize,
    sync_interval: f64,
    sync_on_ack: bool,
    kind: SchedulerKind,
    summary: RunSummary,
    /// Coefficient of variation of per-front-end dispatch counts.
    gateway_skew: f64,
    /// Full run telemetry (`SimResult::telemetry_json`).
    telemetry: Json,
}

/// CV of the dispatch counts (population std-dev over mean).
fn dispatch_cv(counts: &[u64]) -> f64 {
    if counts.len() <= 1 {
        return 0.0;
    }
    let mut stats = crate::util::stats::OnlineStats::new();
    for &c in counts {
        stats.push(c as f64);
    }
    if stats.mean() == 0.0 {
        return 0.0;
    }
    stats.std() / stats.mean()
}

pub fn run(ctx: &ExpContext) -> Result<()> {
    let (fe_points, sync_points) = sweep_axes(ctx.scale);
    let n = ctx.scale.requests_for(SWEEP_QPS);

    let mut grid = Vec::new();
    for &frontends in &fe_points {
        for &sync_interval in &sync_points {
            for kind in KINDS {
                grid.push((frontends, sync_interval, false, kind));
                if sync_interval > 0.0 {
                    // The ack-piggyback variant, with its per-dispatch
                    // serialization cost charged.
                    grid.push((frontends, sync_interval, true, kind));
                }
            }
        }
    }
    let points = parallel_map(
        ctx.jobs,
        &grid,
        |&(frontends, sync_interval, sync_on_ack, kind)| -> Result<Point> {
            let mut cfg = paper_cluster(kind);
            cfg.frontends = frontends;
            cfg.sync_interval = sync_interval;
            cfg.shard_policy = ctx.shard;
            cfg.sync_on_ack = sync_on_ack;
            let res = run_experiment(
                cfg,
                &sharegpt_workload(SWEEP_QPS, n, ctx.seed),
                SimOptions { probes: false, ..SimOptions::default() },
            )?;
            Ok(Point {
                frontends,
                sync_interval,
                sync_on_ack,
                kind,
                summary: res.metrics.summary(),
                gateway_skew: dispatch_cv(&res.frontend_dispatches),
                telemetry: res.telemetry_json(),
            })
        },
    );

    let mut out = JsonObj::new();
    out.insert("qps", SWEEP_QPS);
    out.insert("shard_policy", ctx.shard.name());
    let mut rows = Vec::new();
    for point in points {
        let p = point?;
        let s = &p.summary;
        rows.push(vec![
            format!("{}", p.frontends),
            format!("{:.1}", p.sync_interval),
            (if p.sync_on_ack { "+ack" } else { "-" }).to_string(),
            p.kind.name().to_string(),
            format!("{:.3}", s.mean_ttft),
            format!("{:.3}", s.p99_ttft),
            format!("{:.2}", s.mean_e2e),
            format!("{:.2}", s.p99_e2e),
            format!("{:.2}", s.mean_overhead * 1e3),
            format!("{}", s.total_preemptions),
            format!("{:.3}", p.gateway_skew),
        ]);
        let mut j = s.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("frontends", p.frontends);
            o.insert("sync_interval", p.sync_interval);
            o.insert("sync_on_ack", p.sync_on_ack);
            o.insert("scheduler", p.kind.name());
            o.insert("gateway_skew", p.gateway_skew);
            o.insert("telemetry", p.telemetry.clone());
        }
        out.insert(
            format!("{}@fe{}s{}{}", p.kind.name(), p.frontends,
                    p.sync_interval,
                    if p.sync_on_ack { "+ack" } else { "" }),
            j,
        );
    }
    println!("Staleness sweep — front-ends × view-sync intervals at \
              {SWEEP_QPS} QPS ({} sharding, {}s of load per point; \
              +ack rows pay the per-dispatch serialization cost)",
             ctx.shard.name(), ctx.scale.duration());
    println!("{}", render_table(
        &["frontends", "sync(s)", "ack", "scheduler", "mean TTFT",
          "p99 TTFT", "mean e2e", "p99 e2e", "ovh(ms)", "preempt",
          "gw skew"],
        &rows));

    ctx.write_json("staleness", &Json::Obj(out))
}
