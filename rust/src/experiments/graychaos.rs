//! Gray-chaos sweep — the gray-failure claim under measurement: a host
//! that is *slow but alive* poisons the whole cluster unless someone
//! notices, and Block's own predictions are the detector.
//!
//! Unlike the fail-stop [`crate::experiments::chaos`] sweep (hosts die,
//! dispatches bounce, the lifecycle sees everything), a gray failure
//! passes every health check: the instance keeps accepting work and
//! completing it — N× slower than predicted.  Every (severity ×
//! detection × scheduler) point runs the same workload with a scripted
//! [`FaultPlan`]: instance 0 is throttled by `factor` for the middle
//! half of the run, then recovers.
//!
//! What the results should show:
//!
//! * **detection off, severity 5× degrades P99 cluster-wide** — a
//!   quarter of dispatches keep landing on the straggler and come back
//!   ~5× late, so the run-level P99 is the straggler's, not the
//!   cluster's;
//! * **detection on bounds the damage** — the residual tracker trips
//!   within a few completions (`detect_latency` in the output), the
//!   slot is quarantined Active → Degraded, survivors absorb the load,
//!   and the goodput dip shrinks vs the detection-off twin;
//! * **prediction enables detection** — the heuristic baselines attach
//!   no per-request prediction, so the residual detector has nothing to
//!   read and their detect-on/off twins coincide: knowledge-based
//!   scheduling is what buys gray-failure robustness;
//! * **slow is not lost** — conservation holds at every point: every
//!   admitted request is served (quarantine redirects, it never drops).
//!
//! Results land in `results/graychaos.json` (`schema: "graychaos/v1"`),
//! validated by the `gray-smoke` CI job.

use anyhow::Result;

use crate::cluster::{run_experiment, SimOptions};
use crate::config::SchedulerKind;
use crate::experiments::{paper_cluster, parallel_map, sharegpt_workload,
                         ExpContext};
use crate::faults::{FaultEvent, FaultKind, FaultPlan, RecoveryStats};
use crate::metrics::{render_table, RunSummary};
use crate::util::json::{Json, JsonObj};

/// Dispatchers compared (same trio as the fail-stop chaos sweep).
const KINDS: [SchedulerKind; 3] = [
    SchedulerKind::Block,
    SchedulerKind::MinQpm,
    SchedulerKind::LlumnixMinus,
];

/// Gray failures hurt in the contended-but-not-saturated region: the
/// 4-instance cluster saturates near ~20 QPS, and 12 QPS leaves the
/// three survivors enough headroom to absorb a quarantined slot.
const SWEEP_QPS: f64 = 12.0;
const N_INSTANCES: usize = 4;
const SLOW_INSTANCE: usize = 0;

/// Severity levels: engine step-time multiplier on the gray instance
/// (1.0 = healthy baseline — the parity point every other level is
/// judged against).
const SEVERITIES: [(&str, f64); 3] =
    [("none", 1.0), ("mild", 3.0), ("severe", 5.0)];

struct Point {
    severity: &'static str,
    factor: f64,
    detect: bool,
    kind: SchedulerKind,
    requests: usize,
    summary: RunSummary,
    recovery: RecoveryStats,
    /// First Active→Degraded transition relative to the injection
    /// instant (None: detection off, heuristic scheduler, or the
    /// tracker never tripped).
    detect_latency: Option<f64>,
    degraded_events: usize,
    /// Full run telemetry (`SimResult::telemetry_json`).
    telemetry: Json,
}

pub fn run(ctx: &ExpContext) -> Result<()> {
    // Smoke grid: Block only, baseline + the severe level, both
    // detection arms — the four points the gray-smoke CI asserts on.
    let (severities, kinds, n): (Vec<(&str, f64)>, Vec<SchedulerKind>, usize) =
        if ctx.smoke {
            (vec![("none", 1.0), ("severe", 5.0)],
             vec![SchedulerKind::Block], 300)
        } else {
            (SEVERITIES.to_vec(), KINDS.to_vec(),
             ctx.scale.requests_for(SWEEP_QPS))
        };
    let span = n as f64 / SWEEP_QPS;
    // Throttle for the middle half of the run: late enough for a
    // pre-fault goodput window, early enough that recovery and the
    // post-restore tail are all on the record.
    let t0 = span / 4.0;
    let recover_at = t0 + span / 2.0;

    let mut grid = Vec::new();
    for &severity in &severities {
        for detect in [false, true] {
            for &kind in &kinds {
                grid.push((severity, detect, kind));
            }
        }
    }
    let points = parallel_map(
        ctx.jobs,
        &grid,
        |&((name, factor), detect, kind)| -> Result<Point> {
            let mut cfg = paper_cluster(kind);
            cfg.n_instances = N_INSTANCES;
            cfg.frontends = 2;
            cfg.sync_interval = 1.0;
            cfg.shard_policy = ctx.shard;
            // `--shards`: slowdown plans are barrier-class and residual
            // detection is barrier-quantized, so every grid point runs
            // the windowed fast path with byte-identical results.
            cfg.shards = ctx.shards;
            cfg.detect.enabled = detect;
            cfg.faults.report_window = (span / 3.0).clamp(1.0, 15.0);
            let plan = FaultPlan::scripted(vec![
                FaultEvent {
                    time: t0,
                    kind: FaultKind::InstanceSlowdown {
                        instance: SLOW_INSTANCE, factor,
                    },
                },
                FaultEvent {
                    time: recover_at,
                    kind: FaultKind::InstanceRecover(SLOW_INSTANCE),
                },
            ]);
            let workload = sharegpt_workload(SWEEP_QPS, n, ctx.seed);
            let opts = SimOptions {
                probes: false,
                fault_plan: Some(plan),
                ..SimOptions::default()
            };
            let res = run_experiment(cfg, &workload, opts)?;
            // Conservation: a gray failure slows requests down, it must
            // never lose one — quarantine redirects, it does not drop.
            anyhow::ensure!(
                res.metrics.len() as u64 + res.recovery.dropped == n as u64,
                "conservation violated at {name}/{kind:?}: {} served + {} \
                 dropped != {n}",
                res.metrics.len(), res.recovery.dropped,
            );
            let detect_latency = res
                .lifecycle
                .iter()
                .find(|ev| ev.state == "degraded")
                .map(|ev| ev.time - t0);
            let degraded_events = res
                .lifecycle
                .iter()
                .filter(|ev| ev.state == "degraded")
                .count();
            Ok(Point {
                severity: name,
                factor,
                detect,
                kind,
                requests: n,
                summary: res.metrics.summary(),
                telemetry: res.telemetry_json(),
                recovery: res.recovery,
                detect_latency,
                degraded_events,
            })
        },
    );

    let mut out = JsonObj::new();
    out.insert("schema", "graychaos/v1");
    out.insert("qps", SWEEP_QPS);
    out.insert("requests_per_point", n);
    out.insert("n_instances", N_INSTANCES);
    out.insert("slow_instance", SLOW_INSTANCE);
    out.insert("injected_at", t0);
    out.insert("recovered_at", recover_at);
    out.insert("shard_policy", ctx.shard.name());
    let mut pts = JsonObj::new();
    let mut rows = Vec::new();
    for point in points {
        let p = point?;
        let s = &p.summary;
        let r = &p.recovery;
        let latency = match p.detect_latency {
            Some(l) => format!("{l:.2}"),
            None => "-".to_string(),
        };
        rows.push(vec![
            p.severity.to_string(),
            format!("{:.0}x", p.factor),
            if p.detect { "on" } else { "off" }.to_string(),
            p.kind.name().to_string(),
            format!("{:.3}", s.p99_ttft),
            format!("{:.2}", s.p99_e2e),
            format!("{:.2}", s.mean_e2e),
            format!("{}", s.n),
            format!("{}", r.dropped),
            format!("{:.2}", r.mean_goodput_dip()),
            latency,
            format!("{}", p.degraded_events),
        ]);
        let mut j = s.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("scheduler", p.kind.name());
            o.insert("severity", p.severity);
            o.insert("factor", p.factor);
            o.insert("detect", p.detect);
            o.insert("requests", p.requests);
            o.insert("degraded_events", p.degraded_events);
            match p.detect_latency {
                Some(l) => o.insert("detect_latency", l),
                None => o.insert("detect_latency", Json::Null),
            }
            o.insert("recovery", r.to_json());
            o.insert("telemetry", p.telemetry.clone());
        }
        pts.insert(
            format!("{}@{}/detect-{}", p.kind.name(), p.severity,
                    if p.detect { "on" } else { "off" }),
            j,
        );
    }
    out.insert("points", Json::Obj(pts));
    println!("Gray-chaos sweep — severity × detection at {SWEEP_QPS} QPS \
              on {N_INSTANCES} instances ({n} requests/point; instance \
              {SLOW_INSTANCE} throttled t={t0:.0}s..{recover_at:.0}s)");
    println!("{}", render_table(
        &["severity", "factor", "detect", "scheduler", "p99 TTFT",
          "p99 e2e", "mean e2e", "served", "drop", "dip",
          "detect_lat(s)", "n_degraded"],
        &rows));

    ctx.write_json("graychaos", &Json::Obj(out))
}
