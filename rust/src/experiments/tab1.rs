//! Table 1 — query length prediction quality.
//!
//! Paper row (RoBERTa regressor): avg error 78.755 tok, avg error rate
//! 24.4%, Acc-50 69.93%, Acc-100 77.15% (10k eval conversations).
//!
//! We evaluate (a) the real learned MLP regressor through the PJRT
//! artifact on the held-out split of the build-time corpus, and (b) the
//! calibrated noisy oracle the scheduling experiments use.

use anyhow::Result;

use crate::experiments::ExpContext;
use crate::metrics::render_table;
use crate::runtime::{ModelRuntime, RegressorTagger};
use crate::tagger::{LengthTagger, NoisyOracleTagger};
use crate::util::json::{Json, JsonObj};
use crate::workload::sharegpt::load_corpus;

struct Eval {
    avg_error: f64,
    avg_error_rate: f64,
    acc50: f64,
    acc100: f64,
}

fn evaluate(pairs: &[(f64, f64)]) -> Eval {
    let n = pairs.len() as f64;
    let errs: Vec<f64> = pairs.iter().map(|(p, t)| (p - t).abs()).collect();
    Eval {
        avg_error: errs.iter().sum::<f64>() / n,
        avg_error_rate: pairs
            .iter()
            .map(|(p, t)| ((p - t) / t.max(1.0)).abs())
            .sum::<f64>()
            / n,
        acc50: errs.iter().filter(|&&e| e < 50.0).count() as f64 / n,
        acc100: errs.iter().filter(|&&e| e < 100.0).count() as f64 / n,
    }
}

pub fn run(ctx: &ExpContext) -> Result<()> {
    let corpus = load_corpus("artifacts/sharegpt_synth.jsonl")?;
    // Same split convention as python/compile/aot.py: last 20% is eval.
    let split = corpus.len() * 4 / 5;
    let eval_set = &corpus[split..];
    let eval_set = match ctx.scale {
        crate::experiments::Scale::Quick => &eval_set[..eval_set.len().min(2000)],
        crate::experiments::Scale::Full => eval_set,
    };

    // (a) PJRT MLP regressor (the RoBERTa stand-in, served by Rust).
    let rt = ModelRuntime::load("artifacts")?;
    let tagger = RegressorTagger::new(&rt);
    let prompts: Vec<&str> = eval_set.iter().map(|r| r.prompt.as_str()).collect();
    let preds = tagger.tag_batch(&prompts)?;
    let mlp_pairs: Vec<(f64, f64)> = preds
        .iter()
        .zip(eval_set)
        .map(|(&p, r)| (p as f64, r.response_tokens as f64))
        .collect();
    let mlp = evaluate(&mlp_pairs);

    // (b) Calibrated noisy oracle (used by the Block* scheduling runs).
    let mut noisy = NoisyOracleTagger::new(0.244, ctx.seed);
    let noisy_pairs: Vec<(f64, f64)> = eval_set
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let req = crate::core::request::Request::new(
                i as u64, 0.0, r.prompt_tokens, r.response_tokens);
            (noisy.tag(&req) as f64, r.response_tokens as f64)
        })
        .collect();
    let noisy_eval = evaluate(&noisy_pairs);

    let rows = vec![
        vec!["avg error (tok)".into(), format!("{:.1}", mlp.avg_error),
             format!("{:.1}", noisy_eval.avg_error), "78.8".into()],
        vec!["avg error rate".into(),
             format!("{:.1}%", mlp.avg_error_rate * 100.0),
             format!("{:.1}%", noisy_eval.avg_error_rate * 100.0),
             "24.4%".into()],
        vec!["Acc-50".into(), format!("{:.1}%", mlp.acc50 * 100.0),
             format!("{:.1}%", noisy_eval.acc50 * 100.0), "69.9%".into()],
        vec!["Acc-100".into(), format!("{:.1}%", mlp.acc100 * 100.0),
             format!("{:.1}%", noisy_eval.acc100 * 100.0), "77.2%".into()],
    ];
    println!("Table 1 — length prediction quality ({} eval samples)",
             eval_set.len());
    println!("{}", render_table(
        &["metric", "MLP regressor (PJRT)", "noisy oracle", "paper RoBERTa"],
        &rows));

    let mut o = JsonObj::new();
    for (name, e) in [("mlp", &mlp), ("noisy_oracle", &noisy_eval)] {
        let mut inner = JsonObj::new();
        inner.insert("avg_error", e.avg_error);
        inner.insert("avg_error_rate", e.avg_error_rate);
        inner.insert("acc50", e.acc50);
        inner.insert("acc100", e.acc100);
        o.insert(name, inner);
    }
    o.insert("n_eval", eval_set.len());
    ctx.write_json("tab1", &Json::Obj(o))
}
