//! Figure 6 (+ Figure 9 CDFs) — request metrics under varying QPS for all
//! seven schedulers, plus SLO capacity (max QPS with TTFT P99 < 3 s).
//!
//! Every (scheduler × QPS) point is an independent simulation, so the
//! sweep fans out over `ctx.jobs` workers; the capacity searches (one
//! bisection per scheduler) run concurrently the same way.  Each point
//! derives its inputs only from `ctx`, so results are identical for any
//! job count.

use anyhow::Result;

use crate::cluster::{run_experiment, SimOptions};
use crate::config::SchedulerKind;
use crate::experiments::{fig6_qps_points, paper_cluster, parallel_map,
                         sharegpt_workload, ExpContext, Scale};
use crate::metrics::capacity::{search_capacity, DEFAULT_SLO_TTFT_P99};
use crate::metrics::{render_table, RunSummary};
use crate::util::json::{Json, JsonObj};

struct Point {
    qps: f64,
    kind: SchedulerKind,
    summary: RunSummary,
    cdf_ttft: Vec<(f64, f64)>,
    cdf_e2e: Vec<(f64, f64)>,
    pstats: Option<crate::scheduler::PredictorStats>,
    /// Full run telemetry (`SimResult::telemetry_json`).
    telemetry: Json,
}

pub fn run(ctx: &ExpContext) -> Result<()> {
    let qps_points = fig6_qps_points(ctx.scale);
    let schedulers = SchedulerKind::ALL;

    let mut grid = Vec::new();
    for &qps in &qps_points {
        for kind in schedulers {
            grid.push((qps, kind));
        }
    }
    let points = parallel_map(ctx.jobs, &grid, |&(qps, kind)| -> Result<Point> {
        let n = ctx.scale.requests_for(qps);
        let res = run_experiment(
            paper_cluster(kind),
            &sharegpt_workload(qps, n, ctx.seed),
            SimOptions { probes: false, ..SimOptions::default() },
        )?;
        Ok(Point {
            qps,
            kind,
            summary: res.metrics.summary(),
            cdf_ttft: res.metrics.cdf_ttft(40),
            cdf_e2e: res.metrics.cdf_e2e(40),
            telemetry: res.telemetry_json(),
            pstats: res.predictor_stats,
        })
    });

    let mut out = JsonObj::new();
    let mut rows = Vec::new();
    for point in points {
        let p = point?;
        let s = &p.summary;
        rows.push(vec![
            format!("{:.0}", p.qps),
            p.kind.name().to_string(),
            format!("{:.3}", s.mean_ttft),
            format!("{:.3}", s.p99_ttft),
            format!("{:.2}", s.mean_e2e),
            format!("{:.2}", s.p99_e2e),
            format!("{:.1}", s.mean_overhead * 1e3),
            format!("{:.2}", s.throughput),
            match &p.pstats {
                Some(ps) => ps.rate_cell(),
                None => "/".into(),
            },
        ]);
        let mut j = s.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("qps", p.qps);
            o.insert("scheduler", p.kind.name());
            if let Some(ps) = &p.pstats {
                o.insert("predictor_stats", ps.to_json());
            }
            o.insert("telemetry", p.telemetry.clone());
            // Figure 9: CDFs at this point.
            o.insert("cdf_ttft",
                     Json::Arr(p.cdf_ttft.iter()
                         .map(|&(v, pr)| Json::Arr(vec![v.into(), pr.into()]))
                         .collect()));
            o.insert("cdf_e2e",
                     Json::Arr(p.cdf_e2e.iter()
                         .map(|&(v, pr)| Json::Arr(vec![v.into(), pr.into()]))
                         .collect()));
        }
        out.insert(format!("{}@{}", p.kind.name(), p.qps), j);
    }
    println!("Figure 6 — request metrics under different QPS \
              ({}s of load per point)", ctx.scale.duration());
    println!("{}", render_table(
        &["qps", "scheduler", "mean TTFT", "p99 TTFT", "mean e2e",
          "p99 e2e", "overhead(ms)", "thpt", "cache/memo/pool%"],
        &rows));

    // Capacity: max QPS under TTFT P99 < 3 s.
    let (lo, hi, precision) = match ctx.scale {
        Scale::Quick => (30.0, 110.0, 1.0),
        Scale::Full => (30.0, 110.0, 0.1),
    };
    let cap_kinds = [SchedulerKind::LlumnixMinus, SchedulerKind::Block,
                     SchedulerKind::BlockStar];
    let capacities = parallel_map(ctx.jobs, &cap_kinds, |&kind| {
        search_capacity(
            |qps| {
                let cap_n = ctx.scale.requests_for(qps);
                run_experiment(paper_cluster(kind),
                               &sharegpt_workload(qps, cap_n, ctx.seed),
                               SimOptions { probes: false, ..SimOptions::default() })
                    .map(|r| r.metrics.summary().p99_ttft)
                    .unwrap_or(f64::INFINITY)
            },
            DEFAULT_SLO_TTFT_P99,
            lo,
            hi,
            precision,
        )
    });
    let mut cap_rows = Vec::new();
    let mut caps = JsonObj::new();
    for (kind, result) in cap_kinds.iter().zip(&capacities) {
        cap_rows.push(vec![kind.name().to_string(),
                           format!("{:.1}", result.capacity)]);
        caps.insert(kind.name(), result.capacity);
    }
    println!("Capacity (max QPS under TTFT P99 < {DEFAULT_SLO_TTFT_P99} s):");
    println!("{}", render_table(&["scheduler", "capacity (QPS)"], &cap_rows));
    out.insert("capacity", caps);

    ctx.write_json("fig6", &Json::Obj(out))
}
