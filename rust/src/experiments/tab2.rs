//! Table 2 (+ Appendix B sweeps) — generality study: capacity of
//! Block / Block* / Llumnix- under setting variants.
//!
//! Paper variants: batch size 24, chunk size 2048, Qwen2-7B, BurstGPT.
//! Expected shape: sub-optimal engine settings *widen* Block's capacity
//! gain; shorter-response workloads (Qwen/BurstGPT) raise absolute
//! capacity and keep Block ahead; Block* cannot run on BurstGPT (length
//! traces carry no prompt text to estimate from).

use anyhow::Result;

use crate::cluster::SimOptions;
use crate::config::{ClusterConfig, SchedulerKind, WorkloadConfig, WorkloadKind};
use crate::core::hw;
use crate::experiments::{parallel_map, paper_cluster, ExpContext, Scale};
use crate::metrics::capacity::{search_capacity, DEFAULT_SLO_TTFT_P99};
use crate::metrics::render_table;
use crate::util::json::{Json, JsonObj};

struct Variant {
    name: &'static str,
    make_cfg: fn(SchedulerKind) -> ClusterConfig,
    workload: WorkloadKind,
    /// Response-length scale (Qwen generates shorter responses on the
    /// same prompts — §6.6).
    response_scale: f64,
    /// Search bracket.
    hi: f64,
    block_star: bool,
}

fn base(k: SchedulerKind) -> ClusterConfig {
    paper_cluster(k)
}

fn bs24(k: SchedulerKind) -> ClusterConfig {
    let mut c = paper_cluster(k);
    c.engine.max_batch_size = 24;
    c
}

fn cs2048(k: SchedulerKind) -> ClusterConfig {
    let mut c = paper_cluster(k);
    c.engine.chunk_size = 2048;
    c
}

fn qwen(k: SchedulerKind) -> ClusterConfig {
    let mut c = paper_cluster(k);
    c.model = hw::QWEN2_7B;
    c
}

const VARIANTS: &[Variant] = &[
    Variant { name: "default", make_cfg: base, workload: WorkloadKind::ShareGpt,
              response_scale: 1.0, hi: 90.0, block_star: true },
    Variant { name: "bs=24", make_cfg: bs24, workload: WorkloadKind::ShareGpt,
              response_scale: 1.0, hi: 90.0, block_star: true },
    Variant { name: "cs=2048", make_cfg: cs2048, workload: WorkloadKind::ShareGpt,
              response_scale: 1.0, hi: 90.0, block_star: true },
    Variant { name: "qwen", make_cfg: qwen, workload: WorkloadKind::ShareGpt,
              response_scale: 0.5, hi: 190.0, block_star: true },
    Variant { name: "burstgpt", make_cfg: base, workload: WorkloadKind::BurstGpt,
              response_scale: 1.0, hi: 190.0, block_star: false },
];

fn run_point(cfg: ClusterConfig, wl: &WorkloadConfig, scale: f64)
             -> Option<crate::cluster::SimResult> {
    let mut requests = crate::workload::generate(wl).ok()?;
    if scale != 1.0 {
        for r in &mut requests {
            r.response_tokens = ((r.response_tokens as f64 * scale).round()
                                 as u32).max(4);
        }
    }
    if cfg.scheduler.uses_estimates() {
        let mut tagger = crate::tagger::NoisyOracleTagger::new(0.244, wl.seed);
        crate::tagger::tag_requests(&mut tagger, &mut requests);
    }
    Some(crate::cluster::ClusterSim::new(
        cfg, SimOptions { probes: false, ..SimOptions::default() })
        .run(&requests))
}

fn measure(cfg: ClusterConfig, wl: &WorkloadConfig, scale: f64) -> f64 {
    run_point(cfg, wl, scale)
        .map_or(f64::INFINITY, |r| r.metrics.summary().p99_ttft)
}

pub fn run(ctx: &ExpContext) -> Result<()> {
    let precision = match ctx.scale {
        Scale::Quick => 2.0,
        Scale::Full => 0.1,
    };
    let mut rows = Vec::new();
    let mut out = JsonObj::new();
    println!("Table 2 — scheduler capacities with setting variants \
              ({}s of load per eval, TTFT P99 < {DEFAULT_SLO_TTFT_P99}s SLO)",
             ctx.scale.duration());
    // Every (variant × scheduler) capacity search is independent: 15
    // bisections fan out over ctx.jobs workers.
    let kinds = [SchedulerKind::Block, SchedulerKind::BlockStar,
                 SchedulerKind::LlumnixMinus];
    let mut grid = Vec::new();
    for v in VARIANTS {
        for kind in kinds {
            grid.push((v, kind));
        }
    }
    let searched = parallel_map(ctx.jobs, &grid, |&(v, kind)| {
        if kind == SchedulerKind::BlockStar && !v.block_star {
            return None;
        }
        let r = search_capacity(
            |qps| {
                let wl = WorkloadConfig {
                    kind: v.workload.clone(),
                    qps,
                    n_requests: ctx.scale.requests_for(qps),
                    seed: ctx.seed,
                };
                measure((v.make_cfg)(kind), &wl, v.response_scale)
            },
            DEFAULT_SLO_TTFT_P99, 10.0, v.hi, precision);
        Some(r.capacity)
    });
    for (vi, v) in VARIANTS.iter().enumerate() {
        let mut j = JsonObj::new();
        let caps = &searched[vi * kinds.len()..(vi + 1) * kinds.len()];
        for (kind, cap) in kinds.iter().zip(caps) {
            if let Some(c) = cap {
                j.insert(kind.name(), *c);
            }
        }
        let block = caps[0].unwrap_or(0.0);
        let star = caps[1];
        let llumnix = caps[2].unwrap_or(0.0);
        let gain = if llumnix > 0.0 {
            (block - llumnix) / llumnix * 100.0
        } else {
            f64::NAN
        };
        let gain_star = star.map(|s| (s - llumnix) / llumnix.max(1e-9) * 100.0);
        rows.push(vec![
            v.name.into(),
            format!("{block:.1}"),
            star.map_or("/".into(), |s| format!("{s:.1}")),
            format!("{llumnix:.1}"),
            match gain_star {
                Some(g) => format!("{gain:.1}%/{g:.1}%"),
                None => format!("{gain:.1}%"),
            },
        ]);
        j.insert("gain_block_pct", gain);
        if let Some(g) = gain_star {
            j.insert("gain_blockstar_pct", g);
        }
        // One confirmation run at the found Block capacity, reporting the
        // prediction-runtime counters (cache hit-rate, pool reuse) at the
        // operating point the capacity claim rests on.
        if block > 0.0 && block.is_finite() {
            let wl = WorkloadConfig {
                kind: v.workload.clone(),
                qps: block,
                n_requests: ctx.scale.requests_for(block),
                seed: ctx.seed,
            };
            if let Some(r) = run_point((v.make_cfg)(SchedulerKind::Block),
                                       &wl, v.response_scale) {
                j.insert("telemetry_at_capacity", r.telemetry_json());
                if let Some(stats) = r.predictor_stats {
                    j.insert("predictor_stats_at_capacity",
                             stats.to_json());
                }
            }
        }
        out.insert(v.name, j);
    }
    println!("{}", render_table(
        &["variant", "Block", "Block*", "Llumnix-", "gain"], &rows));
    ctx.write_json("tab2", &Json::Obj(out))
}
