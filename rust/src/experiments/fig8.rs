//! Figure 8 — auto-provisioning: `preempt` (predicted-latency trigger)
//! vs `relief` (actual-latency trigger) vs a sufficient static cluster.
//!
//! Paper setup: start with 6 instances at QPS 24 (overloaded), threshold
//! 70 s, backup pool up to 10, static-10 baseline.  Expected shape:
//! preempt provisions earlier and fewer instances, cutting P99 ~20% and
//! >threshold requests ~81% vs relief.

use anyhow::Result;

use crate::cluster::{ClusterSim, SimOptions, SimResult};
use crate::config::SchedulerKind;
use crate::experiments::{parallel_map, paper_cluster, sharegpt_workload,
                         ExpContext};
use crate::metrics::render_table;
use crate::util::json::{Json, JsonObj};
use crate::util::stats::{mean, percentile, variance};
use crate::workload::generate;

/// Load chosen to overload the 6-instance starting cluster by ~35% (the
/// paper's QPS 24 against a 12-instance capacity of ~28 is the same
/// relative overload; our simulated capacity is ~77 QPS at 12 instances —
/// see EXPERIMENTS.md §Calibration).
const OVERLOAD_QPS: f64 = 52.0;

struct Variant {
    name: &'static str,
    predictive: bool,
    enabled: bool,
    initial: usize,
}

pub fn run(ctx: &ExpContext) -> Result<()> {
    let n = (OVERLOAD_QPS * ctx.scale.duration() * 3.0) as usize;
    // The latency threshold scales with run length: the paper's 70 s
    // trigger assumes a ~10-minute overload window; a quick run only
    // accumulates ~40 s of backlog.
    let threshold = match ctx.scale {
        crate::experiments::Scale::Quick => 25.0,
        crate::experiments::Scale::Full => 70.0,
    };
    let variants = [
        Variant { name: "preempt", predictive: true, enabled: true, initial: 6 },
        Variant { name: "relief", predictive: false, enabled: true, initial: 6 },
        Variant { name: "static-10", predictive: false, enabled: false,
                  initial: 10 },
    ];

    // The three provisioning strategies are independent runs over the
    // same workload — fan them out.
    let results = parallel_map(ctx.jobs, &variants, |v| -> Result<SimResult> {
        let mut cfg = paper_cluster(SchedulerKind::Block);
        cfg.n_instances = v.initial;
        cfg.provision.enabled = v.enabled;
        cfg.provision.predictive = v.predictive;
        cfg.provision.threshold = threshold;
        cfg.provision.initial_instances = v.initial;
        cfg.provision.max_instances = 10;
        let requests = generate(&sharegpt_workload(OVERLOAD_QPS, n, ctx.seed))?;
        Ok(ClusterSim::new(cfg, SimOptions { probes: true, ..SimOptions::default() })
            .run(&requests))
    });

    let mut out = JsonObj::new();
    let mut rows = Vec::new();
    for (v, res) in variants.iter().zip(results) {
        let res = res?;
        let e2e = res.metrics.e2es();
        let over: usize = e2e.iter().filter(|&&x| x > threshold).count();
        let final_size = res.size_timeline.last().unwrap().1;
        let var_series: Vec<f64> = res.probes.iter()
            .map(|p| variance(&p.free_blocks.iter().map(|&b| b as f64)
                              .collect::<Vec<_>>()))
            .collect();
        rows.push(vec![
            v.name.into(),
            format!("{:.1}", mean(&e2e)),
            format!("{:.1}", percentile(&e2e, 99.0)),
            format!("{over}"),
            format!("{final_size}"),
            format!("{}", res.provision_events.len()),
            format!("{:.0}", mean(&var_series)),
            res.predictor_stats
                .as_ref()
                .map_or("/".into(), |ps| ps.rate_cell()),
        ]);
        let mut j = JsonObj::new();
        j.insert("mean_e2e", mean(&e2e));
        j.insert("p99_e2e", percentile(&e2e, 99.0));
        j.insert("over_threshold", over);
        j.insert("final_size", final_size);
        if let Some(ps) = &res.predictor_stats {
            j.insert("predictor_stats", ps.to_json());
        }
        j.insert("telemetry", res.telemetry_json());
        j.insert("provision_events",
                 Json::Arr(res.provision_events.iter().map(|e| {
                     let mut o = JsonObj::new();
                     o.insert("time", e.time);
                     o.insert("instance", e.instance);
                     o.insert("trigger_latency", e.trigger_latency);
                     Json::Obj(o)
                 }).collect()));
        j.insert("size_timeline",
                 Json::Arr(res.size_timeline.iter()
                           .map(|&(t, s)| Json::Arr(vec![t.into(), s.into()]))
                           .collect()));
        // Latency-over-time for the timeline plot.
        let mut lat: Vec<(f64, f64)> = res.metrics.records.iter()
            .map(|m| (m.finish, m.e2e())).collect();
        lat.sort_by(|a, b| a.0.total_cmp(&b.0));
        j.insert("latency_timeline",
                 Json::Arr(lat.iter().step_by((lat.len() / 200).max(1))
                           .map(|&(t, l)| Json::Arr(vec![t.into(), l.into()]))
                           .collect()));
        out.insert(v.name, j);
    }
    println!("Figure 8 — auto-provisioning at QPS {OVERLOAD_QPS} \
              (6 initial instances, threshold {threshold}s, {n} reqs)");
    println!("{}", render_table(
        &["strategy", "mean e2e", "p99 e2e", ">thresh reqs", "final size",
          "provisions", "mean blocks var", "cache/memo/pool%"],
        &rows));
    ctx.write_json("fig8", &Json::Obj(out))
}
