//! Scheduler decision traces: what the policy saw, what it predicted,
//! what it chose — and, once the request finishes, what actually
//! happened.
//!
//! Each dispatch decision produces one [`DecisionRecord`]; completions
//! back-annotate the record for the request's *latest* dispatch (a
//! bounced request re-dispatches and gets a fresh record) so the
//! predicted-vs-actual residual of the effective placement is exact.
//! Two export formats:
//!
//! * [`DecisionTrace::to_jsonl`] — one compact JSON object per line,
//!   the raw decision log.
//! * [`DecisionTrace::to_chrome_trace`] — Chrome trace-event JSON
//!   (loadable in Perfetto / `chrome://tracing`): annotated decisions
//!   become complete (`ph:"X"`) slices on the chosen instance's track
//!   spanning arrival → finish; unannotated ones become instants.

use std::collections::HashMap;

use crate::scheduler::PredictorStats;
use crate::util::json::{Json, JsonObj};

/// One scheduling decision, with its post-hoc annotation.
#[derive(Debug, Clone, PartialEq)]
pub struct DecisionRecord {
    pub id: u64,
    /// Request arrival time at the front-end (governing clock).
    pub arrival: f64,
    /// When the decision was made.
    pub time: f64,
    pub frontend: usize,
    /// Chosen instance (the argmin for the Block family).
    pub chosen: usize,
    /// Scheduling overhead charged to the request (seconds).
    pub overhead: f64,
    /// Predicted e2e on the chosen instance (None for heuristics).
    pub predicted_e2e: Option<f64>,
    /// Full candidate set: (instance, predicted e2e).  Empty for
    /// heuristic schedulers that evaluate no predictions.
    pub candidates: Vec<(usize, f64)>,
    /// Predictor cache/memo/pool activity attributable to this
    /// decision (counter delta across the `pick` call).
    pub stats_delta: Option<PredictorStats>,
    /// Measured e2e, filled in when the request finishes.
    pub actual_e2e: Option<f64>,
    /// Instance the request actually finished on (differs from
    /// `chosen` only if this record was superseded by a re-dispatch).
    pub actual_instance: Option<usize>,
}

impl DecisionRecord {
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("id", self.id);
        o.insert("arrival", self.arrival);
        o.insert("t", self.time);
        o.insert("frontend", self.frontend);
        o.insert("chosen", self.chosen);
        o.insert("overhead", self.overhead);
        if let Some(p) = self.predicted_e2e {
            o.insert("predicted_e2e", p);
        }
        o.insert(
            "candidates",
            self.candidates
                .iter()
                .map(|&(i, p)| {
                    let mut c = JsonObj::new();
                    c.insert("instance", i);
                    c.insert("predicted_e2e", p);
                    Json::Obj(c)
                })
                .collect::<Vec<_>>(),
        );
        if let Some(s) = &self.stats_delta {
            o.insert("predictor", s.to_json());
        }
        if let Some(a) = self.actual_e2e {
            o.insert("actual_e2e", a);
            if let Some(p) = self.predicted_e2e {
                o.insert("residual", a - p);
            }
        }
        if let Some(i) = self.actual_instance {
            o.insert("actual_instance", i);
        }
        Json::Obj(o)
    }
}

/// Append-only log of [`DecisionRecord`]s with an id → latest-record
/// index for back-annotation.
#[derive(Debug, Clone, Default)]
pub struct DecisionTrace {
    records: Vec<DecisionRecord>,
    latest: HashMap<u64, usize>,
}

impl DecisionTrace {
    pub fn new() -> Self {
        DecisionTrace::default()
    }

    pub fn record(&mut self, rec: DecisionRecord) {
        self.latest.insert(rec.id, self.records.len());
        self.records.push(rec);
    }

    /// Back-annotate the latest decision for `id` with the measured
    /// outcome.  No-op if the request was never traced (e.g. the ring
    /// started mid-run on the wire).
    pub fn annotate(&mut self, id: u64, instance: usize, e2e: f64) {
        if let Some(&idx) = self.latest.get(&id) {
            let r = &mut self.records[idx];
            r.actual_e2e = Some(e2e);
            r.actual_instance = Some(instance);
        }
    }

    pub fn records(&self) -> &[DecisionRecord] {
        &self.records
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Count of records whose outcome has been filled in.
    pub fn annotated(&self) -> usize {
        self.records.iter().filter(|r| r.actual_e2e.is_some()).count()
    }

    /// Raw decision log: one compact JSON object per line.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for r in &self.records {
            out.push_str(&r.to_json().to_string_compact());
            out.push('\n');
        }
        out
    }

    /// Chrome trace-event JSON (Perfetto-compatible).
    ///
    /// Annotated decisions become `ph:"X"` complete events on
    /// `tid = actual instance`, `ts = arrival`, `dur = actual e2e`
    /// (microseconds).  Unannotated decisions become `ph:"i"`
    /// instants at decision time.
    pub fn to_chrome_trace(&self) -> Json {
        let us = 1.0e6;
        let mut events: Vec<Json> = Vec::with_capacity(self.records.len());
        for r in &self.records {
            let mut e = JsonObj::new();
            e.insert("name", format!("req {}", r.id));
            e.insert("cat", "dispatch");
            e.insert("pid", r.frontend);
            let mut args = JsonObj::new();
            args.insert("id", r.id);
            args.insert("chosen", r.chosen);
            if let Some(p) = r.predicted_e2e {
                args.insert("predicted_e2e", p);
            }
            match (r.actual_e2e, r.actual_instance) {
                (Some(a), Some(i)) => {
                    e.insert("ph", "X");
                    e.insert("tid", i);
                    e.insert("ts", r.arrival * us);
                    e.insert("dur", a * us);
                    args.insert("actual_e2e", a);
                    if let Some(p) = r.predicted_e2e {
                        args.insert("residual", a - p);
                    }
                }
                _ => {
                    e.insert("ph", "i");
                    e.insert("s", "t");
                    e.insert("tid", r.chosen);
                    e.insert("ts", r.time * us);
                }
            }
            e.insert("args", args);
            events.push(Json::Obj(e));
        }
        let mut top = JsonObj::new();
        top.insert("traceEvents", events);
        top.insert("displayTimeUnit", "ms");
        Json::Obj(top)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, chosen: usize) -> DecisionRecord {
        DecisionRecord {
            id,
            arrival: 1.0,
            time: 1.25,
            frontend: 0,
            chosen,
            overhead: 0.01,
            predicted_e2e: Some(2.0),
            candidates: vec![(0, 3.0), (chosen, 2.0)],
            stats_delta: None,
            actual_e2e: None,
            actual_instance: None,
        }
    }

    #[test]
    fn annotate_targets_latest_dispatch() {
        let mut t = DecisionTrace::new();
        t.record(rec(7, 1));
        t.record(rec(7, 2)); // re-dispatch after a bounce
        t.annotate(7, 2, 4.5);
        assert_eq!(t.annotated(), 1);
        assert!(t.records()[0].actual_e2e.is_none());
        assert_eq!(t.records()[1].actual_e2e, Some(4.5));
        assert_eq!(t.records()[1].actual_instance, Some(2));
    }

    #[test]
    fn chrome_trace_complete_event_spans_arrival_to_finish() {
        let mut t = DecisionTrace::new();
        t.record(rec(1, 2));
        t.annotate(1, 2, 3.0);
        let j = t.to_chrome_trace();
        let evs = j.field("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].field("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(evs[0].field("ts").unwrap().as_f64().unwrap(), 1.0e6);
        assert_eq!(evs[0].field("dur").unwrap().as_f64().unwrap(), 3.0e6);
        let res = evs[0].field("args").unwrap().field("residual").unwrap();
        assert_eq!(res.as_f64().unwrap(), 1.0);
    }

    #[test]
    fn jsonl_round_trips_per_line() {
        let mut t = DecisionTrace::new();
        t.record(rec(1, 2));
        t.record(rec(2, 0));
        let text = t.to_jsonl();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let j = Json::parse(line).unwrap();
            assert!(j.field("candidates").unwrap().as_arr().unwrap().len() == 2);
        }
    }
}
