//! Request flight recorder: a bounded ring of structured lifecycle
//! events stamped with the governing clock.
//!
//! The recorder never allocates per event beyond the ring slot and
//! never inspects simulator state — every hook hands it a fully-formed
//! [`FlightKind`].  When the ring is full the oldest event is evicted
//! and `dropped` is bumped, so the tail of a long run is always
//! retained and the loss is visible.

use std::collections::VecDeque;

use crate::util::json::{Json, JsonObj};

/// One structured lifecycle milestone.
///
/// `id` is the request id where a request is involved; `instance` /
/// `frontend` are slot indexes into the run's instance / front-end
/// tables.
#[derive(Debug, Clone, PartialEq)]
pub enum FlightKind {
    /// Request entered the system at a front-end.
    Arrival { id: u64, frontend: usize },
    /// Front-end chose a target; `predicted_e2e` is the scheduler's
    /// winning estimate when the policy produced one.
    Decision {
        id: u64,
        frontend: usize,
        instance: usize,
        predicted_e2e: Option<f64>,
    },
    /// Dispatch landed on a serving instance and was enqueued.
    Land { id: u64, instance: usize },
    /// Dispatch arrived at a dead/draining instance and bounced back
    /// for re-dispatch.
    Bounce { id: u64, instance: usize },
    /// An engine step milestone (recorded only at trace level `full`).
    Step { instance: usize },
    /// Request finished decoding; `e2e` is the measured latency.
    Finish { id: u64, instance: usize, e2e: f64 },
    /// A fault-plan event fired against `target` (instance or
    /// front-end slot, per the kind).
    Fault { kind: &'static str, target: usize },
    /// Elasticity lifecycle transition on an instance slot.
    Lifecycle { instance: usize, state: &'static str },
}

impl FlightKind {
    pub fn name(&self) -> &'static str {
        match self {
            FlightKind::Arrival { .. } => "arrival",
            FlightKind::Decision { .. } => "decision",
            FlightKind::Land { .. } => "land",
            FlightKind::Bounce { .. } => "bounce",
            FlightKind::Step { .. } => "step",
            FlightKind::Finish { .. } => "finish",
            FlightKind::Fault { .. } => "fault",
            FlightKind::Lifecycle { .. } => "lifecycle",
        }
    }

    fn fill(&self, o: &mut JsonObj) {
        match *self {
            FlightKind::Arrival { id, frontend } => {
                o.insert("id", id);
                o.insert("frontend", frontend);
            }
            FlightKind::Decision {
                id,
                frontend,
                instance,
                predicted_e2e,
            } => {
                o.insert("id", id);
                o.insert("frontend", frontend);
                o.insert("instance", instance);
                if let Some(p) = predicted_e2e {
                    o.insert("predicted_e2e", p);
                }
            }
            FlightKind::Land { id, instance } | FlightKind::Bounce { id, instance } => {
                o.insert("id", id);
                o.insert("instance", instance);
            }
            FlightKind::Step { instance } => {
                o.insert("instance", instance);
            }
            FlightKind::Finish { id, instance, e2e } => {
                o.insert("id", id);
                o.insert("instance", instance);
                o.insert("e2e", e2e);
            }
            FlightKind::Fault { kind, target } => {
                o.insert("fault", kind);
                o.insert("target", target);
            }
            FlightKind::Lifecycle { instance, state } => {
                o.insert("instance", instance);
                o.insert("state", state);
            }
        }
    }
}

/// A recorded milestone: governing-clock timestamp plus a global
/// sequence number (total order of recording, stable across shard
/// counts by construction of the barrier merge).
#[derive(Debug, Clone, PartialEq)]
pub struct FlightEvent {
    pub time: f64,
    pub seq: u64,
    pub kind: FlightKind,
}

impl FlightEvent {
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("seq", self.seq);
        o.insert("t", self.time);
        o.insert("kind", self.kind.name());
        self.kind.fill(&mut o);
        Json::Obj(o)
    }
}

/// Bounded ring of [`FlightEvent`]s.
#[derive(Debug, Clone)]
pub struct FlightRecorder {
    ring: VecDeque<FlightEvent>,
    capacity: usize,
    recorded: u64,
    dropped: u64,
}

impl FlightRecorder {
    pub fn new(capacity: usize) -> Self {
        FlightRecorder {
            ring: VecDeque::with_capacity(capacity.min(4096)),
            capacity,
            recorded: 0,
            dropped: 0,
        }
    }

    /// Append an event, stamping the next global sequence number.
    /// Evicts the oldest entry when the ring is at capacity; a
    /// zero-capacity recorder counts but retains nothing.
    pub fn record(&mut self, time: f64, kind: FlightKind) {
        let seq = self.recorded;
        self.recorded += 1;
        if self.capacity == 0 {
            self.dropped += 1;
            return;
        }
        if self.ring.len() == self.capacity {
            self.ring.pop_front();
            self.dropped += 1;
        }
        self.ring.push_back(FlightEvent { time, seq, kind });
    }

    /// Events currently retained, oldest first.
    pub fn events(&self) -> impl Iterator<Item = &FlightEvent> {
        self.ring.iter()
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Total events ever recorded (including evicted ones).
    pub fn recorded(&self) -> u64 {
        self.recorded
    }

    /// Events evicted from the ring because it was full.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("capacity", self.capacity);
        o.insert("recorded", self.recorded);
        o.insert("dropped", self.dropped);
        o.insert(
            "events",
            self.ring.iter().map(|e| e.to_json()).collect::<Vec<_>>(),
        );
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_evicts_oldest_and_counts_drops() {
        let mut r = FlightRecorder::new(3);
        for i in 0..5u64 {
            r.record(i as f64, FlightKind::Step { instance: i as usize });
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.recorded(), 5);
        assert_eq!(r.dropped(), 2);
        let seqs: Vec<u64> = r.events().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![2, 3, 4]);
    }

    #[test]
    fn event_json_carries_kind_fields() {
        let mut r = FlightRecorder::new(8);
        r.record(
            1.5,
            FlightKind::Finish {
                id: 42,
                instance: 3,
                e2e: 0.75,
            },
        );
        let j = r.to_json();
        let ev = &j.field("events").unwrap().as_arr().unwrap()[0];
        assert_eq!(ev.field("kind").unwrap().as_str().unwrap(), "finish");
        assert_eq!(ev.field("id").unwrap().as_usize().unwrap(), 42);
        assert_eq!(ev.field("e2e").unwrap().as_f64().unwrap(), 0.75);
    }
}
