//! Unified observability tier: the request flight recorder, the
//! scheduler decision tracer, and the live metrics registry.
//!
//! All three components share one contract: **free when off**.  With
//! the default [`crate::config::ObsConfig`] nothing here is even
//! constructed — the simulator's hooks are `Option` checks on a `None`,
//! no RNG is touched, no events are pushed, and disabled-observability
//! runs reproduce current runs byte for byte (pinned by
//! `obs_disabled_reproduces_baseline_exactly`).
//!
//! * [`recorder::FlightRecorder`] — a bounded ring buffer of structured
//!   request-lifecycle events (arrival → dispatch decision → land /
//!   bounce → step milestones → finish, plus fault injections), stamped
//!   with the governing clock (virtual seconds in the simulator, scaled
//!   wall seconds on the wire).  Under the sharded event loop, in-window
//!   events are buffered per shard and merged at window barriers in the
//!   exact order the serial run would have recorded them (see
//!   `DESIGN.md` §Observability for the merge rule).
//! * [`trace::DecisionTrace`] — one record per dispatch decision: the
//!   candidate set with per-candidate predicted e2e, the predictor's
//!   cache/memo provenance for the decision, and the chosen argmin;
//!   completions back-annotate the actual e2e so per-decision
//!   prediction residuals become a dumpable artifact
//!   (`simulate --trace out.json`: Chrome trace-event JSON for
//!   Perfetto, plus a raw JSONL decision log).
//! * [`registry::MetricsRegistry`] — counters, gauges, and fixed-bucket
//!   histograms rendered in the Prometheus text exposition format.
//!   The simulator snapshots its registry into
//!   [`crate::cluster::SimResult`]; the wire gateway and instance
//!   daemons serve theirs live at `GET /metrics`.

pub mod recorder;
pub mod registry;
pub mod trace;

pub use recorder::{FlightEvent, FlightKind, FlightRecorder};
pub use registry::MetricsRegistry;
pub use trace::{DecisionRecord, DecisionTrace};

use crate::util::json::{Json, JsonObj};

/// Everything the observability tier captured over one simulator run;
/// `Some` on [`crate::cluster::SimResult::obs`] only when any obs
/// component was enabled.
#[derive(Debug, Clone)]
pub struct ObsReport {
    pub flight: FlightRecorder,
    pub trace: DecisionTrace,
    /// End-of-run snapshot of the live registry (`None` when
    /// `obs.metrics` was off).
    pub registry: Option<MetricsRegistry>,
}

impl ObsReport {
    /// Compact summary for result envelopes (the full artifacts are
    /// dumped separately by `simulate --trace`).
    pub fn summary_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("flight_events", self.flight.len());
        o.insert("flight_dropped", self.flight.dropped());
        o.insert("flight_recorded", self.flight.recorded());
        o.insert("decisions", self.trace.len());
        o.insert("annotated", self.trace.annotated());
        o.insert("metrics", self.registry.is_some());
        Json::Obj(o)
    }
}
