//! Live metrics registry: counters, gauges, and fixed-bucket
//! histograms rendered in the Prometheus text exposition format.
//!
//! Series are keyed by `(name, sorted labels)` in a `BTreeMap`, so
//! rendering order is deterministic regardless of update order.  The
//! registry is plain data — the simulator owns one directly; the wire
//! roles each build one on demand from their live counters when
//! `GET /metrics` is scraped.

use std::collections::BTreeMap;

use crate::util::json::{Json, JsonObj};

/// Default latency buckets (seconds) for e2e / TTFT histograms.
pub const LATENCY_BUCKETS: &[f64] =
    &[0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 60.0, 120.0];

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SeriesType {
    Counter,
    Gauge,
    Histogram,
}

impl SeriesType {
    fn name(self) -> &'static str {
        match self {
            SeriesType::Counter => "counter",
            SeriesType::Gauge => "gauge",
            SeriesType::Histogram => "histogram",
        }
    }
}

#[derive(Debug, Clone)]
enum Value {
    Counter(u64),
    Gauge(f64),
    Histogram(Histogram),
}

/// Fixed-bucket histogram with cumulative Prometheus semantics.
#[derive(Debug, Clone)]
struct Histogram {
    /// Upper bounds, strictly increasing; an implicit `+Inf` bucket
    /// follows.
    bounds: Vec<f64>,
    /// Per-bucket (non-cumulative) counts; `counts.len() == bounds.len() + 1`,
    /// the last slot being the `+Inf` bucket.
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

impl Histogram {
    fn new(bounds: &[f64]) -> Self {
        Histogram {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
            sum: 0.0,
            count: 0,
        }
    }

    fn observe(&mut self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or_else(|| self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.count += 1;
    }
}

/// Series identity: metric name + sorted label pairs.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
struct SeriesKey {
    name: String,
    labels: Vec<(String, String)>,
}

/// Registry of counters, gauges, and histograms.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    series: BTreeMap<SeriesKey, Value>,
    types: BTreeMap<String, SeriesType>,
}

fn key(name: &str, labels: &[(&str, &str)]) -> SeriesKey {
    let mut ls: Vec<(String, String)> = labels
        .iter()
        .map(|&(k, v)| (k.to_string(), v.to_string()))
        .collect();
    ls.sort();
    SeriesKey { name: name.to_string(), labels: ls }
}

/// Escape a label value per the Prometheus text format: backslash,
/// double-quote, and newline.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

fn render_labels(labels: &[(String, String)], extra: Option<(&str, String)>) -> String {
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{}=\"{}\"", k, escape_label(v)))
        .collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{}=\"{}\"", k, v));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

/// Format a sample value the way Prometheus expects (`+Inf`-safe,
/// integral values without a fraction).
fn fmt_value(v: f64) -> String {
    if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{}", v)
    }
}

impl MetricsRegistry {
    pub fn new() -> Self {
        MetricsRegistry::default()
    }

    fn touch_type(&mut self, name: &str, t: SeriesType) {
        self.types.entry(name.to_string()).or_insert(t);
    }

    /// Increment a counter by 1.
    pub fn inc(&mut self, name: &str, labels: &[(&str, &str)]) {
        self.add(name, labels, 1);
    }

    /// Increment a counter by `by`.
    pub fn add(&mut self, name: &str, labels: &[(&str, &str)], by: u64) {
        self.touch_type(name, SeriesType::Counter);
        let k = key(name, labels);
        match self.series.get_mut(&k) {
            Some(Value::Counter(c)) => *c += by,
            Some(_) => {}
            None => {
                self.series.insert(k, Value::Counter(by));
            }
        }
    }

    /// Set a gauge to `v`.
    pub fn gauge_set(&mut self, name: &str, labels: &[(&str, &str)], v: f64) {
        self.touch_type(name, SeriesType::Gauge);
        let k = key(name, labels);
        match self.series.get_mut(&k) {
            Some(Value::Gauge(g)) => *g = v,
            Some(_) => {}
            None => {
                self.series.insert(k, Value::Gauge(v));
            }
        }
    }

    /// Observe `v` into a histogram with [`LATENCY_BUCKETS`].
    pub fn observe(&mut self, name: &str, labels: &[(&str, &str)], v: f64) {
        self.observe_with(name, labels, v, LATENCY_BUCKETS);
    }

    /// Observe `v` into a histogram with explicit bucket bounds (used
    /// on first touch; later observations reuse the existing bounds).
    pub fn observe_with(
        &mut self,
        name: &str,
        labels: &[(&str, &str)],
        v: f64,
        bounds: &[f64],
    ) {
        self.touch_type(name, SeriesType::Histogram);
        let k = key(name, labels);
        match self.series.get_mut(&k) {
            Some(Value::Histogram(h)) => h.observe(v),
            Some(_) => {}
            None => {
                let mut h = Histogram::new(bounds);
                h.observe(v);
                self.series.insert(k, Value::Histogram(h));
            }
        }
    }

    /// Read back a counter (tests / snapshot assertions).
    pub fn counter_value(&self, name: &str, labels: &[(&str, &str)]) -> u64 {
        match self.series.get(&key(name, labels)) {
            Some(Value::Counter(c)) => *c,
            _ => 0,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.series.is_empty()
    }

    /// Render the whole registry in the Prometheus text exposition
    /// format (version 0.0.4): `# TYPE` headers, escaped label values,
    /// cumulative histogram buckets with a `+Inf` terminal bucket plus
    /// `_sum` / `_count` samples.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let mut last_name: Option<&str> = None;
        for (k, v) in &self.series {
            if last_name != Some(k.name.as_str()) {
                let t = self.types.get(&k.name).copied().unwrap_or(SeriesType::Gauge);
                out.push_str(&format!("# TYPE {} {}\n", k.name, t.name()));
                last_name = Some(k.name.as_str());
            }
            match v {
                Value::Counter(c) => {
                    out.push_str(&format!(
                        "{}{} {}\n",
                        k.name,
                        render_labels(&k.labels, None),
                        c
                    ));
                }
                Value::Gauge(g) => {
                    out.push_str(&format!(
                        "{}{} {}\n",
                        k.name,
                        render_labels(&k.labels, None),
                        fmt_value(*g)
                    ));
                }
                Value::Histogram(h) => {
                    let mut cum = 0u64;
                    for (i, &b) in h.bounds.iter().enumerate() {
                        cum += h.counts[i];
                        out.push_str(&format!(
                            "{}_bucket{} {}\n",
                            k.name,
                            render_labels(&k.labels, Some(("le", fmt_value(b)))),
                            cum
                        ));
                    }
                    cum += h.counts[h.bounds.len()];
                    out.push_str(&format!(
                        "{}_bucket{} {}\n",
                        k.name,
                        render_labels(&k.labels, Some(("le", "+Inf".to_string()))),
                        cum
                    ));
                    out.push_str(&format!(
                        "{}_sum{} {}\n",
                        k.name,
                        render_labels(&k.labels, None),
                        h.sum
                    ));
                    out.push_str(&format!(
                        "{}_count{} {}\n",
                        k.name,
                        render_labels(&k.labels, None),
                        h.count
                    ));
                }
            }
        }
        out
    }

    /// JSON snapshot (stored in `SimResult` envelopes).
    pub fn to_json(&self) -> Json {
        let mut arr: Vec<Json> = Vec::with_capacity(self.series.len());
        for (k, v) in &self.series {
            let mut o = JsonObj::new();
            o.insert("name", k.name.as_str());
            if !k.labels.is_empty() {
                let mut lo = JsonObj::new();
                for (lk, lv) in &k.labels {
                    lo.insert(lk.as_str(), lv.as_str());
                }
                o.insert("labels", lo);
            }
            match v {
                Value::Counter(c) => {
                    o.insert("type", "counter");
                    o.insert("value", *c);
                }
                Value::Gauge(g) => {
                    o.insert("type", "gauge");
                    o.insert("value", *g);
                }
                Value::Histogram(h) => {
                    o.insert("type", "histogram");
                    o.insert("sum", h.sum);
                    o.insert("count", h.count);
                    o.insert("bounds", h.bounds.clone());
                    o.insert(
                        "counts",
                        h.counts.iter().map(|&c| c as f64).collect::<Vec<_>>(),
                    );
                }
            }
            arr.push(Json::Obj(o));
        }
        Json::Arr(arr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_render_with_type_headers() {
        let mut r = MetricsRegistry::new();
        r.inc("block_arrivals_total", &[]);
        r.inc("block_arrivals_total", &[]);
        r.gauge_set("block_active_instances", &[], 4.0);
        let text = r.render();
        assert!(text.contains("# TYPE block_arrivals_total counter\n"));
        assert!(text.contains("block_arrivals_total 2\n"));
        assert!(text.contains("# TYPE block_active_instances gauge\n"));
        assert!(text.contains("block_active_instances 4\n"));
    }

    #[test]
    fn label_values_are_escaped() {
        let mut r = MetricsRegistry::new();
        r.inc("x_total", &[("path", "a\\b\"c\nd")]);
        let text = r.render();
        assert!(text.contains("x_total{path=\"a\\\\b\\\"c\\nd\"} 1\n"));
    }

    #[test]
    fn one_type_header_per_name_across_label_sets() {
        let mut r = MetricsRegistry::new();
        r.inc("block_dispatches_total", &[("instance", "0")]);
        r.inc("block_dispatches_total", &[("instance", "1")]);
        let text = r.render();
        assert_eq!(text.matches("# TYPE block_dispatches_total").count(), 1);
        assert!(text.contains("block_dispatches_total{instance=\"0\"} 1\n"));
        assert!(text.contains("block_dispatches_total{instance=\"1\"} 1\n"));
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_monotone() {
        let mut r = MetricsRegistry::new();
        for v in [0.05, 0.2, 0.2, 3.0, 500.0] {
            r.observe("block_e2e_seconds", &[], v);
        }
        let text = r.render();
        // Parse back every bucket line and check monotone non-decreasing
        // cumulative counts ending in the +Inf total.
        let mut prev = 0u64;
        let mut saw_inf = false;
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("block_e2e_seconds_bucket{le=\"") {
                let (le, count) = rest.split_once("\"} ").unwrap();
                let c: u64 = count.parse().unwrap();
                assert!(c >= prev, "bucket counts must be cumulative");
                prev = c;
                if le == "+Inf" {
                    saw_inf = true;
                    assert_eq!(c, 5);
                }
            }
        }
        assert!(saw_inf, "terminal +Inf bucket required");
        assert!(text.contains("block_e2e_seconds_count 5\n"));
        let sum_line = text
            .lines()
            .find(|l| l.starts_with("block_e2e_seconds_sum"))
            .unwrap();
        let sum: f64 = sum_line.rsplit(' ').next().unwrap().parse().unwrap();
        assert!((sum - 503.45).abs() < 1e-9);
    }

    #[test]
    fn boundary_observation_lands_in_le_bucket() {
        let mut r = MetricsRegistry::new();
        r.observe_with("b_seconds", &[], 1.0, &[1.0, 2.0]);
        let text = r.render();
        assert!(text.contains("b_seconds_bucket{le=\"1\"} 1\n"));
        assert!(text.contains("b_seconds_bucket{le=\"2\"} 1\n"));
        assert!(text.contains("b_seconds_bucket{le=\"+Inf\"} 1\n"));
    }

    #[test]
    fn json_snapshot_round_trips() {
        let mut r = MetricsRegistry::new();
        r.inc("a_total", &[("k", "v")]);
        r.observe_with("h_seconds", &[], 0.3, &[0.5, 1.0]);
        let j = Json::parse(&r.to_json().to_string_compact()).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        let a = &arr[0];
        assert_eq!(a.field("name").unwrap().as_str().unwrap(), "a_total");
        assert_eq!(
            a.field("labels").unwrap().field("k").unwrap().as_str().unwrap(),
            "v"
        );
        assert_eq!(a.field("value").unwrap().as_usize().unwrap(), 1);
    }
}
