//! `cargo bench --bench cluster` — the macro benchmark: whole-cluster
//! simulation throughput at 16 / 128 / 1024 instances, single-heap
//! (`shards = 1`) vs sharded (`shards = 8`) execution.
//!
//! Each size runs the same min-qpm workload through both backends and
//! reports events/sec and requests/sec; byte parity between the two is
//! asserted on every pair (the bench doubles as an end-to-end parity
//! gate at scales the property tests don't reach).  Results land in
//! `BENCH_cluster.json` at the repo root so the mega-scale trajectory
//! is tracked PR over PR.
//!
//! `-- --smoke` shrinks to one small size so CI can validate the JSON
//! schema and the parity assertion without paying for the 1024x1M run.

use std::time::Instant;

use block::cluster::{run_experiment, SimOptions, SimResult};
use block::config::{ClusterConfig, SchedulerKind, WorkloadConfig,
                    WorkloadKind};
use block::util::json::{Json, JsonObj};

fn bench_cfg(n_instances: usize, shards: usize) -> ClusterConfig {
    let mut cfg = ClusterConfig {
        n_instances,
        scheduler: SchedulerKind::MinQpm,
        ..ClusterConfig::default()
    };
    // Distributed stale-view deployment: the shape the windowed
    // sharded path accelerates (and the paper's serving shape).
    cfg.frontends = 4;
    cfg.sync_interval = 1.0;
    cfg.window = 0.25;
    cfg.shards = shards;
    cfg.jobs = shards.max(1);
    cfg
}

fn run_once(n_instances: usize, shards: usize, wl: &WorkloadConfig)
            -> SimResult {
    run_experiment(
        bench_cfg(n_instances, shards),
        wl,
        SimOptions { probes: false, ..SimOptions::default() },
    )
    .expect("bench run failed")
}

/// The parity gate: identical request records and event counts across
/// backends.  Panics (failing the bench) on any divergence.
fn assert_parity(base: &SimResult, got: &SimResult, n: usize,
                 shards: usize) {
    let recs = |r: &SimResult| {
        r.metrics
            .records
            .iter()
            .map(|m| (m.id, m.instance, m.dispatched, m.finish))
            .collect::<Vec<_>>()
    };
    assert_eq!(recs(base), recs(got),
               "parity violated at instances={n} shards={shards}");
    assert_eq!(base.events_processed, got.events_processed,
               "event count diverged at instances={n} shards={shards}");
}

struct RunStat {
    shards: usize,
    events: u64,
    requests: usize,
    wall_s: f64,
}

impl RunStat {
    fn events_per_s(&self) -> f64 {
        self.events as f64 / self.wall_s.max(1e-9)
    }

    fn requests_per_s(&self) -> f64 {
        self.requests as f64 / self.wall_s.max(1e-9)
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // (instances, requests): the 1024-instance point is the paper's
    // O(1000) mega-scale tier at >= 1M requests.
    let sizes: &[(usize, usize)] = if smoke {
        &[(16, 2_000)]
    } else {
        &[(16, 50_000), (128, 200_000), (1024, 1_000_000)]
    };
    const SHARDED: usize = 8;

    let mut runs = JsonObj::new();
    for &(n, n_requests) in sizes {
        let wl = WorkloadConfig {
            kind: WorkloadKind::ShareGpt,
            qps: 12.0 * n as f64,
            n_requests,
            seed: 7,
        };
        let mut stats = Vec::new();
        let mut base: Option<SimResult> = None;
        for shards in [1usize, SHARDED] {
            let t0 = Instant::now();
            let res = run_once(n, shards, &wl);
            let wall = t0.elapsed().as_secs_f64();
            println!(
                "instances={n:<5} shards={shards:<2} {:>12} events  \
                 {:>10.0} ev/s  {:>9.0} req/s  ({wall:.2}s)",
                res.events_processed,
                res.events_processed as f64 / wall.max(1e-9),
                res.metrics.len() as f64 / wall.max(1e-9),
            );
            stats.push(RunStat {
                shards,
                events: res.events_processed,
                requests: res.metrics.len(),
                wall_s: wall,
            });
            match &base {
                None => base = Some(res),
                Some(b) => assert_parity(b, &res, n, shards),
            }
        }
        let mut run = JsonObj::new();
        run.insert("requests", n_requests);
        run.insert("peak_instances", n);
        for s in &stats {
            let mut o = JsonObj::new();
            o.insert("events", s.events as f64);
            o.insert("wall_s", s.wall_s);
            o.insert("events_per_s", s.events_per_s());
            o.insert("requests_per_s", s.requests_per_s());
            run.insert(format!("shards={}", s.shards), Json::Obj(o));
        }
        let speedup = stats[0].wall_s / stats[1].wall_s.max(1e-9);
        run.insert("speedup", speedup);
        println!("instances={n:<5} sharded speedup {speedup:.2}x");
        runs.insert(format!("instances={n}"), Json::Obj(run));
    }

    let mut root = JsonObj::new();
    root.insert("schema", "bench-cluster/v1");
    root.insert("smoke", smoke);
    root.insert("generated_by", "cargo bench --bench cluster");
    root.insert("scheduler", "min-qpm");
    root.insert("sharded_shards", SHARDED);
    root.insert("runs", Json::Obj(runs));
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_cluster.json");
    let json = Json::Obj(root).to_string_pretty();
    if let Err(e) = std::fs::write(out, json) {
        eprintln!("failed to write {out}: {e}");
        std::process::exit(1);
    }
    println!("[written {out}]");
}
