//! `cargo bench --bench cluster` — the macro benchmark: whole-cluster
//! simulation throughput, single-heap (`shards = 1`) vs sharded
//! (`shards = 8`) execution, across the knob-eligibility matrix.
//!
//! Two axes:
//!
//! * **Size** (vanilla config only): 16 / 128 / 1024 instances, the
//!   1024 point being the paper's O(1000) mega-scale tier at >= 1M
//!   requests.
//! * **Knob config** (at 128 instances): `vanilla`, `+provision`
//!   (relief auto-provisioning + idle scale-down), `+detect`
//!   (gray-failure residual detection), `+echo+ack` (local echo and
//!   ack-piggybacked view syncs) — the barrier-quantized knobs whose
//!   serialized-fallback exclusions were lifted, i.e. the knob space
//!   of the chaos / gray-chaos / elasticity sweeps.  Each config's row
//!   proves the windowed fast path survives the knob (`serial_events`
//!   strictly below the run's total event count) and still speeds up.
//!
//! Each cell runs the same min-qpm workload through both backends and
//! reports events/sec and requests/sec; byte parity between the two is
//! asserted on every pair (the bench doubles as an end-to-end parity
//! gate at scales the property tests don't reach).  Results land in
//! `BENCH_cluster.json` (`bench-cluster/v2`) at the repo root so the
//! mega-scale trajectory is tracked PR over PR.
//!
//! `-- --smoke` shrinks every cell to 16 instances / 2k requests so CI
//! can validate the JSON schema, the parity assertion, and the
//! per-config fast-path assertion without paying for the 1024x1M run.
//!
//! Caveat on `+provision`: min-qpm produces no latency predictions, so
//! the *preemptive* trigger and the residual detector's observation
//! stream are inert under it — the config exercises the relief trigger
//! and the idle scale-down machinery, which is what the elasticity
//! sweep runs.

use std::time::Instant;

use block::cluster::{run_experiment, SimOptions, SimResult};
use block::config::{ClusterConfig, SchedulerKind, WorkloadConfig,
                    WorkloadKind};
use block::util::json::{Json, JsonObj};

fn bench_cfg(n_instances: usize, shards: usize) -> ClusterConfig {
    let mut cfg = ClusterConfig {
        n_instances,
        scheduler: SchedulerKind::MinQpm,
        ..ClusterConfig::default()
    };
    // Distributed stale-view deployment: the shape the windowed
    // sharded path accelerates (and the paper's serving shape).
    cfg.frontends = 4;
    cfg.sync_interval = 1.0;
    cfg.window = 0.25;
    cfg.shards = shards;
    cfg.jobs = shards.max(1);
    cfg
}

fn knob_vanilla(_cfg: &mut ClusterConfig) {}

fn knob_provision(cfg: &mut ClusterConfig) {
    let n = cfg.n_instances;
    cfg.provision.enabled = true;
    // Relief trigger (observed latency), not preemptive: min-qpm has
    // no predictions to feed the preemptive path.
    cfg.provision.predictive = false;
    cfg.provision.initial_instances = n;
    cfg.provision.max_instances = n + (n / 8).max(1);
    cfg.provision.threshold = 25.0;
    cfg.provision.cold_start = 5.0;
    cfg.provision.scale_down_idle = 10.0;
}

fn knob_detect(cfg: &mut ClusterConfig) {
    cfg.detect.enabled = true;
}

fn knob_echo_ack(cfg: &mut ClusterConfig) {
    cfg.sync_on_ack = true;
    cfg.local_echo = true;
}

/// The eligibility matrix: `(config key, runs all sizes?, knob setter)`.
const CONFIGS: &[(&str, bool, fn(&mut ClusterConfig))] = &[
    ("vanilla", true, knob_vanilla),
    ("provision", false, knob_provision),
    ("detect", false, knob_detect),
    ("echo_ack", false, knob_echo_ack),
];

fn run_once(n_instances: usize, shards: usize, wl: &WorkloadConfig,
            knob: fn(&mut ClusterConfig)) -> SimResult {
    let mut cfg = bench_cfg(n_instances, shards);
    knob(&mut cfg);
    run_experiment(
        cfg,
        wl,
        SimOptions { probes: false, ..SimOptions::default() },
    )
    .expect("bench run failed")
}

/// The parity gate: identical request records and event counts across
/// backends.  Panics (failing the bench) on any divergence.
fn assert_parity(base: &SimResult, got: &SimResult, n: usize,
                 shards: usize) {
    let recs = |r: &SimResult| {
        r.metrics
            .records
            .iter()
            .map(|m| (m.id, m.instance, m.dispatched, m.finish))
            .collect::<Vec<_>>()
    };
    assert_eq!(recs(base), recs(got),
               "parity violated at instances={n} shards={shards}");
    assert_eq!(base.events_processed, got.events_processed,
               "event count diverged at instances={n} shards={shards}");
}

struct RunStat {
    shards: usize,
    events: u64,
    requests: usize,
    wall_s: f64,
    windows: u64,
    serial_events: u64,
}

impl RunStat {
    fn events_per_s(&self) -> f64 {
        self.events as f64 / self.wall_s.max(1e-9)
    }

    fn requests_per_s(&self) -> f64 {
        self.requests as f64 / self.wall_s.max(1e-9)
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // (instances, requests) per matrix column.  The knob configs run
    // the 128-instance point only — the eligibility matrix is about
    // which knobs keep the fast path, not about re-measuring scale.
    let all_sizes: &[(usize, usize)] = if smoke {
        &[(16, 2_000)]
    } else {
        &[(16, 50_000), (128, 200_000), (1024, 1_000_000)]
    };
    let knob_sizes: &[(usize, usize)] = if smoke {
        &[(16, 2_000)]
    } else {
        &[(128, 200_000)]
    };
    const SHARDED: usize = 8;

    let mut configs = JsonObj::new();
    for &(config, every_size, knob) in CONFIGS {
        let sizes = if every_size { all_sizes } else { knob_sizes };
        let mut runs = JsonObj::new();
        for &(n, n_requests) in sizes {
            let wl = WorkloadConfig {
                kind: WorkloadKind::ShareGpt,
                qps: 12.0 * n as f64,
                n_requests,
                seed: 7,
            };
            let mut stats = Vec::new();
            let mut base: Option<SimResult> = None;
            for shards in [1usize, SHARDED] {
                let t0 = Instant::now();
                let res = run_once(n, shards, &wl, knob);
                let wall = t0.elapsed().as_secs_f64();
                println!(
                    "config={config:<9} instances={n:<5} shards={shards:<2} \
                     {:>12} events  {:>10.0} ev/s  {:>9.0} req/s  \
                     ({wall:.2}s)",
                    res.events_processed,
                    res.events_processed as f64 / wall.max(1e-9),
                    res.metrics.len() as f64 / wall.max(1e-9),
                );
                let (windows, serial_events) = match &res.sync_stats {
                    Some(s) => (s.windows, s.serial_events),
                    None => (0, res.events_processed),
                };
                if shards > 1 {
                    // The whole point of the matrix: every config in
                    // it is window-overlap eligible, so the sharded
                    // run must take the windowed fast path, not the
                    // serialized fallback.
                    let ss = res.sync_stats.as_ref()
                        .expect("sharded run reports sync stats");
                    assert!(ss.serialized_reason.is_none(),
                            "config={config}: sharded run fell back to \
                             the serialized path: {:?}",
                            ss.serialized_reason);
                    assert!(ss.serial_events < res.events_processed,
                            "config={config}: no events ran windowed \
                             ({} serial of {})",
                            ss.serial_events, res.events_processed);
                }
                stats.push(RunStat {
                    shards,
                    events: res.events_processed,
                    requests: res.metrics.len(),
                    wall_s: wall,
                    windows,
                    serial_events,
                });
                match &base {
                    None => base = Some(res),
                    Some(b) => assert_parity(b, &res, n, shards),
                }
            }
            let mut run = JsonObj::new();
            run.insert("requests", n_requests);
            run.insert("peak_instances", n);
            for s in &stats {
                let mut o = JsonObj::new();
                o.insert("events", s.events as f64);
                o.insert("wall_s", s.wall_s);
                o.insert("events_per_s", s.events_per_s());
                o.insert("requests_per_s", s.requests_per_s());
                o.insert("windows", s.windows as f64);
                o.insert("serial_events", s.serial_events as f64);
                run.insert(format!("shards={}", s.shards), Json::Obj(o));
            }
            let speedup = stats[0].wall_s / stats[1].wall_s.max(1e-9);
            run.insert("speedup", speedup);
            println!("config={config:<9} instances={n:<5} sharded \
                      speedup {speedup:.2}x");
            runs.insert(format!("instances={n}"), Json::Obj(run));
        }
        configs.insert(config, Json::Obj(runs));
    }

    let mut root = JsonObj::new();
    root.insert("schema", "bench-cluster/v2");
    root.insert("smoke", smoke);
    root.insert("generated_by", "cargo bench --bench cluster");
    root.insert("scheduler", "min-qpm");
    root.insert("sharded_shards", SHARDED);
    root.insert("configs", Json::Obj(configs));
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_cluster.json");
    let json = Json::Obj(root).to_string_pretty();
    if let Err(e) = std::fs::write(out, json) {
        eprintln!("failed to write {out}: {e}");
        std::process::exit(1);
    }
    println!("[written {out}]");
}
