//! `cargo bench --bench micro` — microbenchmarks of the L3 hot paths:
//! predictor forward simulation (reference vs pooled paths, with/without
//! the latency cache and the prediction memo), engine stepping,
//! block-manager churn, event-queue throughput, scheduler decision
//! latency, JSON parsing.
//!
//! Hand-rolled harness (criterion unavailable offline): warmup + timed
//! iterations, reporting mean and p99 per op.  Results are also written
//! to `BENCH_micro.json` at the repo root so the perf trajectory is
//! tracked PR over PR; the `comparisons` section pairs pre-refactor
//! ("before") ops with their optimized ("after") counterparts.
//!
//! `-- --smoke` runs tiny iteration counts (CI keeps the binary alive
//! and validates the JSON without paying for a full measurement).

use std::time::Instant;

use block::config::{EngineConfig, OverheadConfig, SchedulerKind};
use block::core::hw::{A30, LLAMA2_7B};
use block::core::request::Request;
use block::engine::InstanceEngine;
use block::exec::roofline::RooflineModel;
use block::predictor::{Predictor, TrueLengths};
use block::scheduler::{build_scheduler, ClusterView};
use block::util::json::{Json, JsonObj};
use block::util::rng::Rng;
use block::util::stats::percentile_sorted;

struct OpStat {
    name: String,
    mean_us: f64,
    p99_us: f64,
    iters: usize,
}

struct Harness {
    smoke: bool,
    ops: Vec<OpStat>,
}

impl Harness {
    /// Time `iters` runs of `f`, printing and recording mean and p99
    /// microseconds.  Returns the mean.
    fn bench<F: FnMut()>(&mut self, name: &str, iters: usize, mut f: F) -> f64 {
        let iters = if self.smoke { iters.min(3) } else { iters };
        // Warmup.
        for _ in 0..iters.div_ceil(10).min(50) {
            f();
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64() * 1e6);
        }
        samples.sort_by(f64::total_cmp);
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        // Shared clamped percentile (a raw `len * 0.99 - 1` index
        // underflows for small iteration counts).
        let p99 = percentile_sorted(&samples, 99.0);
        println!(
            "{name:<46} {mean:>10.2} us/op  p99 {p99:>10.2} us  ({iters} iters)"
        );
        self.ops.push(OpStat { name: name.into(), mean_us: mean, p99_us: p99, iters });
        mean
    }

    fn mean_of(&self, name: &str) -> Option<f64> {
        self.ops.iter().find(|o| o.name == name).map(|o| o.mean_us)
    }

    fn write_json(&self, path: &str) {
        let mut ops = JsonObj::new();
        for op in &self.ops {
            let mut o = JsonObj::new();
            o.insert("mean_us", op.mean_us);
            o.insert("p99_us", op.p99_us);
            o.insert("iters", op.iters);
            ops.insert(op.name.clone(), Json::Obj(o));
        }
        let mut root = JsonObj::new();
        root.insert("schema", "bench-micro/v1");
        root.insert("smoke", self.smoke);
        root.insert("generated_by", "cargo bench --bench micro");
        root.insert("ops", Json::Obj(ops));
        // Before/after pairs for the predictor hot path: "before" is the
        // pre-refactor clone-and-rebuild pipeline kept as
        // `predict_with_pending_reference` / the scheduler reference
        // path; "after" is the pooled + memoized runtime.
        let mut comparisons = JsonObj::new();
        for (label, before, after) in [
            ("predictor.per_candidate",
             "predictor.per_candidate.before (load=8)",
             "predictor.per_candidate.after (load=8)"),
            ("predictor.reprobe.unchanged",
             "predictor.reprobe.unchanged.before (12 cand)",
             "predictor.reprobe.unchanged.after (12 cand)"),
            ("block.fanout.serial",
             "block fan-out (8 candidates, jobs=1, reference)",
             "block fan-out (8 candidates, jobs=1)"),
        ] {
            if let (Some(b), Some(a)) = (self.mean_of(before), self.mean_of(after)) {
                let mut c = JsonObj::new();
                c.insert("before_op", before);
                c.insert("after_op", after);
                c.insert("before_mean_us", b);
                c.insert("after_mean_us", a);
                c.insert("speedup_mean", if a > 0.0 { b / a } else { f64::NAN });
                comparisons.insert(label, Json::Obj(c));
            }
        }
        root.insert("comparisons", Json::Obj(comparisons));
        let json = Json::Obj(root).to_string_pretty();
        if let Err(e) = std::fs::write(path, json) {
            eprintln!("failed to write {path}: {e}");
            std::process::exit(1);
        }
        println!("[written {path}]");
    }
}

fn loaded_engine(n: usize) -> InstanceEngine {
    let cost = RooflineModel::from_profiles(&A30, &LLAMA2_7B);
    let mut eng = InstanceEngine::new(EngineConfig::default(), 1056);
    for i in 0..n {
        eng.enqueue(&Request::new(i as u64, 0.0, 100 + (i as u32 * 37) % 500,
                                  20 + (i as u32 * 13) % 300), 0.0);
    }
    for _ in 0..6 {
        if eng.start_step(&cost).is_some() {
            eng.finish_step();
            eng.take_finished();
        }
    }
    eng
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut h = Harness { smoke, ops: Vec::new() };
    let cost = RooflineModel::from_profiles(&A30, &LLAMA2_7B);

    // Predictor forward simulation — the Block dispatch hot path.
    // "before": the pre-refactor clone-and-rebuild pipeline (kept as the
    // parity reference).  "after": pooled engines reset in place.  Both
    // run over a warmed latency cache, as in steady-state serving.
    for load in [8usize, 24, 48] {
        let eng = loaded_engine(load);
        let status = eng.snapshot();
        let candidate = Request::new(9999, 0.0, 200, 80);
        let pred = Predictor::new(eng.cfg.clone(), eng.total_blocks());
        pred.predict(&status, &candidate, &cost, &TrueLengths); // warm
        h.bench(&format!("predictor.per_candidate.before (load={load})"),
                200, || {
            std::hint::black_box(pred.predict_with_pending_reference(
                &status, &candidate, &cost, &TrueLengths, &[]));
        });
        h.bench(&format!("predictor.per_candidate.after (load={load})"),
                200, || {
            std::hint::black_box(
                pred.predict(&status, &candidate, &cost, &TrueLengths));
        });
        // Uncached replay: the stable "no latency cache" baseline (the
        // old cold-cache op re-ran `Predictor::new` inside the timing
        // loop, so after the fixed-capacity cache rewrite it measured
        // table zeroing, not prediction cost).
        h.bench(&format!("predictor.predict (load={load}, uncached)"),
                50, || {
            std::hint::black_box(pred.predict_uncached(
                &status, &candidate, &cost, &TrueLengths));
        });
    }

    // Unchanged-instance re-probe: the same arrival shape against the
    // same 12-instance view, repeatedly.  "before" re-simulates every
    // candidate; "after" hits the per-instance prediction memo.
    {
        let statuses: Vec<_> = (0..12)
            .map(|i| Some(loaded_engine(12 + 3 * (i % 4)).snapshot()))
            .collect();
        let req = Request::new(4242, 0.0, 180, 60);
        let mk = |reference: bool| {
            let mut s = build_scheduler(
                SchedulerKind::Block, 12, &EngineConfig::default(), 1056,
                &OverheadConfig::default(), 7, 1);
            s.set_reference_path(reference);
            s
        };
        let mut before = mk(true);
        h.bench("predictor.reprobe.unchanged.before (12 cand)", 100, || {
            let view = ClusterView { now: 0.0, statuses: &statuses,
                                     in_transit: &[], loads: &[] };
            std::hint::black_box(before.pick(&req, &view, &cost));
        });
        let mut after = mk(false);
        h.bench("predictor.reprobe.unchanged.after (12 cand)", 100, || {
            let view = ClusterView { now: 0.0, statuses: &statuses,
                                     in_transit: &[], loads: &[] };
            std::hint::black_box(after.pick(&req, &view, &cost));
        });
    }

    // Engine step loop.
    h.bench("engine.start_step+finish_step (batch ~40)", 300, || {
        let mut eng = loaded_engine(40);
        if eng.start_step(&cost).is_some() {
            eng.finish_step();
        }
        std::hint::black_box(&eng);
    });

    // Snapshot export (the status API).
    let eng = loaded_engine(48);
    h.bench("engine.snapshot (48 seqs)", 2000, || {
        std::hint::black_box(eng.snapshot());
    });

    // Block manager churn.
    h.bench("block_manager alloc/grow/free cycle", 2000, || {
        let mut bm = block::engine::block_manager::BlockManager::new(1056, 16, 0.01);
        for i in 0..48u64 {
            bm.allocate_seq(i, 300);
        }
        for i in 0..48u64 {
            bm.grow_to(i, 400);
        }
        for i in 0..48u64 {
            bm.free_seq(i);
        }
        std::hint::black_box(bm.free_blocks());
    });

    // Event queue throughput.
    h.bench("event_queue push+pop x1000", 500, || {
        use block::cluster::events::{Event, EventKind, EventQueue};
        let mut q = EventQueue::new();
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            q.push(Event { time: rng.next_f64(), kind: EventKind::InstanceReady });
        }
        while q.pop().is_some() {}
    });

    // Heuristic scheduler decision latency (lightweight-loads path).
    let statuses: Vec<_> = (0..12)
        .map(|_| Some(loaded_engine(24).snapshot()))
        .collect();
    for kind in [SchedulerKind::RoundRobin, SchedulerKind::LlumnixMinus] {
        let mut s = build_scheduler(kind, 12, &EngineConfig::default(), 1056,
                                    &OverheadConfig::default(), 7, 1);
        let req = Request::new(1, 0.0, 100, 50);
        h.bench(&format!("scheduler.pick ({})", kind.name()), 2000, || {
            let view = ClusterView { now: 0.0, statuses: &statuses,
                                     in_transit: &[], loads: &[] };
            std::hint::black_box(s.pick(&req, &view, &cost));
        });
    }

    // Block's per-candidate fan-out: serial vs parallel prediction at
    // 4/8/16 candidate instances.  Every candidate carries real load so
    // each forward simulation is deep enough to be worth a thread.  The
    // candidate prompt varies per pick so the prediction memo cannot
    // short-circuit the comparison (this measures the replay pipeline).
    for n_cand in [4usize, 8, 16] {
        let statuses: Vec<_> = (0..n_cand)
            .map(|i| Some(loaded_engine(16 + 4 * (i % 5)).snapshot()))
            .collect();
        for (jobs, reference) in [(1usize, true), (1, false), (4, false),
                                  (8, false)] {
            if jobs > n_cand {
                continue;
            }
            // Fresh per config so every (jobs, reference) op sees the
            // same prompt-length sequence — apples-to-apples speedups.
            let mut probe = 0u32;
            let mut s = build_scheduler(
                SchedulerKind::Block, n_cand, &EngineConfig::default(), 1056,
                &OverheadConfig::default(), 7, jobs);
            s.set_reference_path(reference);
            let suffix = if reference { ", reference" } else { "" };
            h.bench(&format!(
                "block fan-out ({n_cand} candidates, jobs={jobs}{suffix})"),
                60, || {
                let view = ClusterView { now: 0.0, statuses: &statuses,
                                         in_transit: &[], loads: &[] };
                probe = probe.wrapping_add(1);
                let req = Request::new(2, 0.0, 150 + probe % 512, 80);
                std::hint::black_box(s.pick(&req, &view, &cost));
            });
        }
    }

    // JSON parse of a corpus line.
    let line = r#"{"category": "qa", "prompt": "what is the capital of the quick brown fox jumping over lazy dogs", "prompt_tokens": 24, "response_tokens": 87}"#;
    h.bench("json.parse corpus line", 5000, || {
        std::hint::black_box(block::util::json::Json::parse(line).unwrap());
    });

    // Machine-readable trajectory at the repo root.
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_micro.json");
    h.write_json(out);
}
