//! `cargo bench --bench micro` — microbenchmarks of the L3 hot paths:
//! predictor forward simulation (with/without the latency cache), engine
//! stepping, block-manager churn, event-queue throughput, scheduler
//! decision latency, JSON parsing.
//!
//! Hand-rolled harness (criterion unavailable offline): warmup + timed
//! iterations, reporting mean and p99 per op.

use std::time::Instant;

use block::config::{EngineConfig, OverheadConfig, SchedulerKind};
use block::core::hw::{A30, LLAMA2_7B};
use block::core::request::Request;
use block::engine::InstanceEngine;
use block::exec::roofline::RooflineModel;
use block::predictor::{Predictor, TrueLengths};
use block::scheduler::{build_scheduler, ClusterView};
use block::util::rng::Rng;

/// Time `iters` runs of `f`, printing mean and p99 microseconds.
fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // Warmup.
    for _ in 0..iters.div_ceil(10).min(50) {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    samples.sort_by(f64::total_cmp);
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p99 = samples[(samples.len() as f64 * 0.99) as usize - 1];
    println!("{name:<44} {mean:>10.2} us/op  p99 {p99:>10.2} us  ({iters} iters)");
}

fn loaded_engine(n: usize) -> InstanceEngine {
    let cost = RooflineModel::from_profiles(&A30, &LLAMA2_7B);
    let mut eng = InstanceEngine::new(EngineConfig::default(), 1056);
    for i in 0..n {
        eng.enqueue(&Request::new(i as u64, 0.0, 100 + (i as u32 * 37) % 500,
                                  20 + (i as u32 * 13) % 300), 0.0);
    }
    for _ in 0..6 {
        if eng.start_step(&cost).is_some() {
            eng.finish_step();
            eng.take_finished();
        }
    }
    eng
}

fn main() {
    let cost = RooflineModel::from_profiles(&A30, &LLAMA2_7B);

    // Predictor forward simulation — the Block dispatch hot path.
    for load in [8usize, 24, 48] {
        let eng = loaded_engine(load);
        let status = eng.snapshot();
        let candidate = Request::new(9999, 0.0, 200, 80);
        let pred = Predictor::new(eng.cfg.clone(), eng.total_blocks());
        bench(&format!("predictor.predict (load={load}, cached)"), 200, || {
            std::hint::black_box(
                pred.predict(&status, &candidate, &cost, &TrueLengths));
        });
        let mut cold = Predictor::new(eng.cfg.clone(), eng.total_blocks());
        bench(&format!("predictor.predict (load={load}, cold cache)"), 50, || {
            cold = Predictor::new(eng.cfg.clone(), eng.total_blocks());
            std::hint::black_box(
                cold.predict(&status, &candidate, &cost, &TrueLengths));
        });
    }

    // Engine step loop.
    bench("engine.start_step+finish_step (batch ~40)", 300, || {
        let mut eng = loaded_engine(40);
        if eng.start_step(&cost).is_some() {
            eng.finish_step();
        }
        std::hint::black_box(&eng);
    });

    // Snapshot export (the status API).
    let eng = loaded_engine(48);
    bench("engine.snapshot (48 seqs)", 2000, || {
        std::hint::black_box(eng.snapshot());
    });

    // Block manager churn.
    bench("block_manager alloc/grow/free cycle", 2000, || {
        let mut bm = block::engine::block_manager::BlockManager::new(1056, 16, 0.01);
        for i in 0..48u64 {
            bm.allocate_seq(i, 300);
        }
        for i in 0..48u64 {
            bm.grow_to(i, 400);
        }
        for i in 0..48u64 {
            bm.free_seq(i);
        }
        std::hint::black_box(bm.free_blocks());
    });

    // Event queue throughput.
    bench("event_queue push+pop x1000", 500, || {
        use block::cluster::events::{Event, EventKind, EventQueue};
        let mut q = EventQueue::new();
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            q.push(Event { time: rng.next_f64(), kind: EventKind::InstanceReady });
        }
        while q.pop().is_some() {}
    });

    // Heuristic scheduler decision latency.
    let statuses: Vec<_> = (0..12)
        .map(|_| Some(loaded_engine(24).snapshot()))
        .collect();
    for kind in [SchedulerKind::RoundRobin, SchedulerKind::LlumnixMinus] {
        let mut s = build_scheduler(kind, 12, &EngineConfig::default(), 1056,
                                    &OverheadConfig::default(), 7, 1);
        let req = Request::new(1, 0.0, 100, 50);
        bench(&format!("scheduler.pick ({})", kind.name()), 2000, || {
            let view = ClusterView { now: 0.0, statuses: &statuses,
                                     in_transit: &[] };
            std::hint::black_box(s.pick(&req, &view, &cost));
        });
    }

    // Block's per-candidate fan-out: serial vs parallel prediction at
    // 4/8/16 candidate instances.  Every candidate carries real load so
    // each forward simulation is deep enough to be worth a thread.
    for n_cand in [4usize, 8, 16] {
        let statuses: Vec<_> = (0..n_cand)
            .map(|i| Some(loaded_engine(16 + 4 * (i % 5)).snapshot()))
            .collect();
        let req = Request::new(2, 0.0, 200, 80);
        for jobs in [1usize, 4, 8] {
            if jobs > n_cand {
                continue;
            }
            let mut s = build_scheduler(
                SchedulerKind::Block, n_cand, &EngineConfig::default(), 1056,
                &OverheadConfig::default(), 7, jobs);
            bench(&format!(
                "block fan-out ({n_cand} candidates, jobs={jobs})"), 60, || {
                let view = ClusterView { now: 0.0, statuses: &statuses,
                                         in_transit: &[] };
                std::hint::black_box(s.pick(&req, &view, &cost));
            });
        }
    }

    // JSON parse of a corpus line.
    let line = r#"{"category": "qa", "prompt": "what is the capital of the quick brown fox jumping over lazy dogs", "prompt_tokens": 24, "response_tokens": 87}"#;
    bench("json.parse corpus line", 5000, || {
        std::hint::black_box(block::util::json::Json::parse(line).unwrap());
    });
}
