//! `cargo bench --bench paper` — regenerate every paper table and figure
//! at Quick scale (criterion is unavailable offline; this is a
//! deterministic experiment driver, not a statistical sampler — each
//! experiment prints the paper's rows/series and its wall time).
//!
//! Full-scale runs: `block experiment all --scale full`.

use std::time::Instant;

use block::experiments::{default_jobs, run, ExpContext, Scale};

fn main() {
    // Struct-update off the default so new context knobs (shard, smoke,
    // ...) cannot silently break this rarely-built bench target again.
    let ctx = ExpContext {
        scale: Scale::Quick,
        out_dir: "results/bench".into(),
        seed: 7,
        jobs: default_jobs(),
        ..ExpContext::default()
    };
    let mut failures = 0;
    for name in ["tab1", "fig5", "fig6", "fig7", "fig8", "tab2"] {
        println!("\n================ bench: {name} ================");
        let t0 = Instant::now();
        match run(name, &ctx) {
            Ok(()) => println!("[{name} done in {:?}]", t0.elapsed()),
            Err(e) => {
                println!("[{name} FAILED: {e:#}]");
                failures += 1;
            }
        }
    }
    if failures > 0 {
        std::process::exit(1);
    }
}
