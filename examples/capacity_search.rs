//! SLO capacity search (the Table-2 methodology): find the max QPS each
//! scheduler sustains with TTFT P99 < 3 s on a small cluster.
//!
//! Run: `cargo run --release --example capacity_search`

use block::cluster::{run_experiment, SimOptions};
use block::config::{ClusterConfig, SchedulerKind, WorkloadConfig, WorkloadKind};
use block::metrics::capacity::{search_capacity, DEFAULT_SLO_TTFT_P99};
use block::metrics::render_table;

fn main() -> anyhow::Result<()> {
    let n_instances = 4;
    let n_requests = 1200;
    let mut rows = Vec::new();
    for scheduler in [SchedulerKind::Random, SchedulerKind::RoundRobin,
                      SchedulerKind::LlumnixMinus, SchedulerKind::Block] {
        let result = search_capacity(
            |qps| {
                let cfg = ClusterConfig { n_instances, scheduler,
                                          ..ClusterConfig::default() };
                let wl = WorkloadConfig { kind: WorkloadKind::ShareGpt, qps,
                                          n_requests, seed: 7 };
                run_experiment(cfg, &wl,
                               SimOptions { probes: false, ..SimOptions::default() })
                    .map(|r| r.metrics.summary().p99_ttft)
                    .unwrap_or(f64::INFINITY)
            },
            DEFAULT_SLO_TTFT_P99,
            8.0,
            40.0,
            0.25,
        );
        println!("{}: capacity {:.2} QPS ({} evaluations)",
                 scheduler.name(), result.capacity, result.evaluations.len());
        rows.push(vec![scheduler.name().to_string(),
                       format!("{:.2}", result.capacity)]);
    }
    println!("\nCapacity under TTFT P99 < {DEFAULT_SLO_TTFT_P99}s \
              ({n_instances} instances):");
    println!("{}", render_table(&["scheduler", "max QPS"], &rows));
    Ok(())
}
