//! Quickstart: simulate a small cluster under load and compare Block
//! against round-robin — the paper's headline claim in 30 seconds.
//!
//! Run: `cargo run --release --example quickstart`

use block::cluster::{run_experiment, SimOptions};
use block::config::{ClusterConfig, SchedulerKind, WorkloadConfig, WorkloadKind};
use block::metrics::render_table;

fn main() -> anyhow::Result<()> {
    let workload = WorkloadConfig {
        kind: WorkloadKind::ShareGpt,
        qps: 22.0,           // just past a 4-instance cluster's knee
        n_requests: 2000,
        seed: 7,
    };

    let mut rows = Vec::new();
    for scheduler in [SchedulerKind::RoundRobin, SchedulerKind::LlumnixMinus,
                      SchedulerKind::Block] {
        let cfg = ClusterConfig { n_instances: 4, scheduler,
                                  ..ClusterConfig::default() };
        let res = run_experiment(cfg, &workload,
                                 SimOptions { probes: false, ..SimOptions::default() })?;
        let s = res.metrics.summary();
        rows.push(vec![
            scheduler.name().to_string(),
            format!("{:.3}", s.mean_ttft),
            format!("{:.3}", s.p99_ttft),
            format!("{:.2}", s.mean_e2e),
            format!("{:.2}", s.p99_e2e),
            format!("{:?}", res.wall_time),
        ]);
    }
    println!("4x A30 instances serving LLaMA2-7B (simulated), ShareGPT-like \
              load at {} QPS, {} requests:\n", workload.qps, workload.n_requests);
    println!("{}", render_table(
        &["scheduler", "mean TTFT(s)", "p99 TTFT(s)", "mean e2e(s)",
          "p99 e2e(s)", "sim wall"],
        &rows));
    println!("Block's predictive dispatch cuts tail TTFT by routing each\n\
              request to the instance whose *simulated future* finishes it\n\
              fastest — see DESIGN.md for how the Predictor works.");
    Ok(())
}
