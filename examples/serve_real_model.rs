//! End-to-end validation: serve batched requests through the REAL model —
//! L1 Pallas attention kernels inside an L2 JAX transformer, AOT-lowered
//! to HLO and executed from Rust via PJRT, with the L3 continuous-batching
//! loop and the learned length tagger on the request path.
//!
//! Requires `make artifacts` first.
//! Run: `cargo run --release --example serve_real_model`

use std::time::Instant;

use block::runtime::serving::{RealServer, ServingRequest};
use block::runtime::{ModelRuntime, RegressorTagger};
use block::util::stats::{mean, percentile};
use block::workload::sharegpt::load_corpus;

const N_REQUESTS: usize = 24;
const MAX_NEW: usize = 24;

fn main() -> anyhow::Result<()> {
    let t0 = Instant::now();
    let rt = ModelRuntime::load("artifacts")?;
    println!("loaded + compiled artifacts in {:?}", t0.elapsed());
    let d = rt.dims();
    println!("model: {} params, {} layers, context {}, buckets {:?}\n",
             d.param_count, d.n_layers, d.max_context, rt.buckets());

    // Real prompts from the build-time corpus.
    let corpus = load_corpus("artifacts/sharegpt_synth.jsonl")?;
    let requests: Vec<ServingRequest> = corpus
        .iter()
        .filter(|r| r.prompt.len() < 200)
        .take(N_REQUESTS)
        .enumerate()
        .map(|(i, r)| ServingRequest {
            id: i as u64,
            prompt: r.prompt.clone(),
            max_new: MAX_NEW,
        })
        .collect();

    // Tag lengths with the PJRT MLP regressor (the paper's ingress step).
    let tagger = RegressorTagger::new(&rt);
    let prompts: Vec<&str> = requests.iter().map(|r| r.prompt.as_str()).collect();
    let tags = tagger.tag_batch(&prompts)?;
    println!("ingress tagging (PJRT length regressor):");
    for (r, t) in requests.iter().zip(&tags).take(4) {
        println!("  '{}…' -> predicted {} tokens",
                 &r.prompt[..r.prompt.len().min(48)], t);
    }
    println!("  … ({} requests tagged)\n", requests.len());

    // Serve with continuous batching.
    let t0 = Instant::now();
    let mut server = RealServer::new(&rt);
    let results = server.serve(&requests)?;
    let wall = t0.elapsed();

    let ttfts: Vec<f64> = results.iter().map(|r| r.ttft.as_secs_f64()).collect();
    let e2es: Vec<f64> = results.iter().map(|r| r.e2e.as_secs_f64()).collect();
    let total_tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
    println!("served {} requests / {} tokens in {:?} \
              ({} prefills, {} decode steps)",
             results.len(), total_tokens, wall, server.prefills(),
             server.decode_steps());
    println!("  throughput: {:.1} tok/s, {:.2} req/s",
             total_tokens as f64 / wall.as_secs_f64(),
             results.len() as f64 / wall.as_secs_f64());
    println!("  TTFT  mean {:.0} ms, p99 {:.0} ms",
             mean(&ttfts) * 1e3, percentile(&ttfts, 99.0) * 1e3);
    println!("  e2e   mean {:.0} ms, p99 {:.0} ms",
             mean(&e2es) * 1e3, percentile(&e2es, 99.0) * 1e3);
    let sample = &results[0];
    println!("\nsample generation (byte-level tiny model, random weights):\n  \
              id={} prompt_tokens={} -> {:?}",
             sample.id, sample.prompt_tokens,
             &sample.tokens[..sample.tokens.len().min(12)]);
    Ok(())
}
