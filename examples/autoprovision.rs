//! Auto-provisioning demo (Figure 8): predicted-latency ("preempt") vs
//! observed-latency ("relief") triggers under an overloaded start.
//!
//! Run: `cargo run --release --example autoprovision`

use block::cluster::{ClusterSim, SimOptions};
use block::config::{ClusterConfig, SchedulerKind, WorkloadConfig, WorkloadKind};
use block::metrics::render_table;
use block::util::stats::{mean, percentile};
use block::workload::generate;

fn main() -> anyhow::Result<()> {
    let workload = WorkloadConfig {
        kind: WorkloadKind::ShareGpt,
        qps: 12.0,          // ~120% of a 2-instance cluster's capacity
        n_requests: 1500,
        seed: 11,
    };
    let threshold = 40.0;

    let mut rows = Vec::new();
    for (name, enabled, predictive, initial) in [
        ("preempt", true, true, 2usize),
        ("relief", true, false, 2),
        ("static-4", false, false, 4),
    ] {
        let mut cfg = ClusterConfig {
            n_instances: initial,
            scheduler: SchedulerKind::Block,
            ..ClusterConfig::default()
        };
        cfg.provision.enabled = enabled;
        cfg.provision.predictive = predictive;
        cfg.provision.threshold = threshold;
        cfg.provision.initial_instances = initial;
        cfg.provision.max_instances = 4;
        cfg.provision.cold_start = 30.0;

        let requests = generate(&workload)?;
        let res = ClusterSim::new(cfg, SimOptions::default()).run(&requests);
        let e2e = res.metrics.e2es();
        let over = e2e.iter().filter(|&&x| x > threshold).count();
        rows.push(vec![
            name.to_string(),
            format!("{:.1}", mean(&e2e)),
            format!("{:.1}", percentile(&e2e, 99.0)),
            format!("{over}"),
            format!("{}", res.size_timeline.last().unwrap().1),
            res.provision_events
                .iter()
                .map(|e| format!("{:.0}s", e.time))
                .collect::<Vec<_>>()
                .join(","),
        ]);
    }
    println!("Auto-provisioning at {} QPS (threshold {}s, cold start 30s):\n",
             workload.qps, threshold);
    println!("{}", render_table(
        &["strategy", "mean e2e", "p99 e2e", ">thresh", "final size",
          "provision times"],
        &rows));
    println!("Preemptive provisioning (trigger on *predicted* latency) acts\n\
              before the backlog forms; relief waits for damage already done.");
    Ok(())
}
