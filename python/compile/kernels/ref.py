"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

These implement the mathematically obvious (materialize-everything) form of
the two attention variants.  pytest + hypothesis assert the Pallas kernels
match these within float32 tolerance across shapes, lengths and dtypes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def decode_attention_ref(q, k, v, lens):
    """[B,H,Dh] x [B,S,H,Dh]^2 x [B] -> [B,H,Dh], masked at lens."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    scores = jnp.einsum("bhd,bshd->bhs", q, k) * scale
    s = k.shape[1]
    pos = jnp.arange(s)[None, None, :]
    scores = jnp.where(pos < lens[:, None, None], scores, NEG)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, v)


def causal_attention_ref(q, k, v, length, q_offset=0):
    """[Sq,H,Dh] x [Sk,H,Dh]^2 -> [Sq,H,Dh]; causal + length mask.

    q[i] sits at absolute position q_offset+i and may attend to k[j] iff
    j <= q_offset+i and j < length.
    """
    scale = 1.0 / (q.shape[-1] ** 0.5)
    scores = jnp.einsum("qhd,khd->hqk", q, k) * scale
    sq, sk = q.shape[0], k.shape[0]
    qpos = q_offset + jnp.arange(sq)[None, :, None]
    kpos = jnp.arange(sk)[None, None, :]
    mask = (kpos <= qpos) & (kpos < length)
    scores = jnp.where(mask, scores, NEG)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hqk,khd->qhd", p, v)
