"""Layer-1 Pallas attention kernels for the Block serving stack.

Two kernels cover the serving hot path:

  * ``decode_attention`` — one query token per sequence attends over a
    length-masked KV cache.  This is the flash-decoding split-KV schedule:
    the grid iterates over (batch, kv-block); each program pulls one KV
    block (a "page") from HBM into VMEM via its BlockSpec, computes partial
    scores on the VPU/MXU, and folds them into an online-softmax
    (m, l, acc) accumulator kept in VMEM scratch.  On a real TPU the
    BlockSpec index maps express the HBM<->VMEM schedule that CUDA kernels
    express with threadblocks + shared memory (see DESIGN.md
    §Hardware-Adaptation).

  * ``chunked_prefill_attention`` — causal flash attention over a prompt,
    tiled (q-block x k-block) so the working set (q tile + k tile + v tile
    + accumulators) fits the ~16 MiB VMEM budget.  Used by the Sarathi-style
    chunked-prefill local scheduler: a prefill *chunk* is a contiguous range
    of q rows, so the same kernel serves both full and chunked prefill.

Both kernels are lowered with ``interpret=True``: the CPU PJRT client
cannot execute Mosaic custom-calls, and correctness is what the CPU path
validates (pytest + hypothesis against ``ref.py``).  Real-TPU efficiency is
estimated from the block shapes in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Finite stand-in for -inf: exp(NEG - m) underflows to exactly 0.0 without
# producing NaNs when an entire block is masked (m stays at NEG).
NEG = -1e30


def _iota(shape, dim):
    return jax.lax.broadcasted_iota(jnp.int32, shape, dim)


# ---------------------------------------------------------------------------
# Decode attention (flash-decoding split-KV)
# ---------------------------------------------------------------------------


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, block_s: int, num_blocks: int, scale: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]          # [H, Dh]
    k = k_ref[0]          # [block_s, H, Dh]
    v = v_ref[0]          # [block_s, H, Dh]
    ln = len_ref[0]       # scalar int32: valid KV length of this sequence

    # Partial scores for this KV block, per head: [H, block_s].
    scores = jnp.einsum("hd,shd->hs", q, k) * scale
    pos = j * block_s + _iota(scores.shape, 1)
    scores = jnp.where(pos < ln, scores, NEG)

    # Online softmax update.
    m_prev = m_ref[...]                         # [H]
    m_new = jnp.maximum(m_prev, scores.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)             # 0 when m_prev == NEG
    p = jnp.exp(scores - m_new[:, None])        # masked entries underflow to 0
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.einsum("hs,shd->hd", p, v)
    m_ref[...] = m_new

    @pl.when(j == num_blocks - 1)
    def _finalize():
        o_ref[0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-9)[:, None]


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(q, k, v, lens, *, block_s: int = 128, interpret: bool = True):
    """Single-token attention over a length-masked KV cache.

    Args:
      q:    [B, H, Dh] query for the token being decoded.
      k, v: [B, S, H, Dh] KV cache (S is the padded max context).
      lens: [B] int32 number of valid cache entries per sequence (>= 1).
      block_s: KV block ("page") size; S must be a multiple of it.

    Returns: [B, H, Dh] attention output.
    """
    b, h, dh = q.shape
    s = k.shape[1]
    if s % block_s != 0:
        raise ValueError(f"context {s} not a multiple of block_s {block_s}")
    nb = s // block_s
    scale = 1.0 / (dh ** 0.5)
    kernel = functools.partial(_decode_kernel, block_s=block_s, num_blocks=nb,
                               scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(b, nb),
        in_specs=[
            pl.BlockSpec((1, h, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, block_s, h, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, block_s, h, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((1, h, dh), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, lens)


# ---------------------------------------------------------------------------
# Chunked-prefill causal attention
# ---------------------------------------------------------------------------


def _prefill_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, acc_ref,
                    *, block_q: int, block_k: int, num_k_blocks: int,
                    scale: float, q_offset_blocks: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]        # [block_q, H, Dh]
    k = k_ref[...]        # [block_k, H, Dh]
    v = v_ref[...]
    ln = len_ref[0]

    scores = jnp.einsum("qhd,khd->hqk", q, k) * scale   # [H, bq, bk]
    qpos = (i + q_offset_blocks) * block_q + _iota(scores.shape, 1)
    kpos = j * block_k + _iota(scores.shape, 2)
    mask = (kpos <= qpos) & (kpos < ln)
    scores = jnp.where(mask, scores, NEG)

    m_prev = m_ref[...]                                  # [H, bq]
    m_new = jnp.maximum(m_prev, scores.max(axis=2))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new[:, :, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=2)
    acc_ref[...] = acc_ref[...] * alpha[:, :, None] + jnp.einsum(
        "hqk,khd->hqd", p, v)
    m_ref[...] = m_new

    @pl.when(j == num_k_blocks - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-9)[:, :, None]
        o_ref[...] = jnp.transpose(out, (1, 0, 2))       # [bq, H, Dh]


@functools.partial(jax.jit,
                   static_argnames=("block_q", "block_k", "q_offset",
                                    "interpret"))
def chunked_prefill_attention(q, k, v, length, *, block_q: int = 128,
                              block_k: int = 128, q_offset: int = 0,
                              interpret: bool = True):
    """Causal flash attention over one prompt (or a chunk of it).

    Args:
      q:      [Sq, H, Dh] queries for the chunk being prefilled.
      k, v:   [Sk, H, Dh] keys/values for all tokens up to and including
              the chunk (Sk >= q_offset + Sq after padding).
      length: scalar int32, number of valid tokens in k/v (padding beyond).
      q_offset: absolute position of q[0] within the sequence — nonzero when
              prefilling a later chunk against the already-cached prefix.

    Returns: [Sq, H, Dh].
    """
    sq, h, dh = q.shape
    sk = k.shape[0]
    if sq % block_q != 0 or sk % block_k != 0 or q_offset % block_q != 0:
        raise ValueError("shapes must be multiples of block sizes")
    nq, nk = sq // block_q, sk // block_k
    scale = 1.0 / (dh ** 0.5)
    kernel = functools.partial(
        _prefill_kernel, block_q=block_q, block_k=block_k, num_k_blocks=nk,
        scale=scale, q_offset_blocks=q_offset // block_q)
    length = jnp.asarray(length, jnp.int32).reshape((1,))
    return pl.pallas_call(
        kernel,
        grid=(nq, nk),
        in_specs=[
            pl.BlockSpec((block_q, h, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((block_k, h, dh), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((block_k, h, dh), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_q, h, dh), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((sq, h, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((h, block_q), jnp.float32),
            pltpu.VMEM((h, block_q), jnp.float32),
            pltpu.VMEM((h, block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, length)
