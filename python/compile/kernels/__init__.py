from .attention import chunked_prefill_attention, decode_attention  # noqa: F401
