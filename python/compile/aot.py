"""AOT compile path: lower every served computation to HLO text + manifest.

Run once by ``make artifacts``.  Emits into ``artifacts/``:

  * ``prefill.hlo.txt``                — prompt encoding (B=1)
  * ``decode_b{1,2,4,8}.hlo.txt``      — one continuous-batching decode step
                                         per batch-size bucket
  * ``length_model.hlo.txt``           — response-length regressor (N=32)
  * ``params/*.bin``                   — raw little-endian f32 weights
  * ``sharegpt_synth.jsonl``           — synthetic ShareGPT corpus
  * ``length_model_eval.json``         — Table-1 metrics on the eval split
  * ``manifest.json``                  — shapes/dtypes/input order for Rust

Interchange format is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Python never runs on the request path — after this script, the Rust binary
is self-contained.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus, length_model, model

DECODE_BUCKETS = [1, 2, 4, 8]
LENGTH_BATCH = 32
CORPUS_N = 50_000
TRAIN_N = 40_000          # first 40k train / last 10k eval (paper's split)

# Serving config: small enough that CPU-PJRT interpret-mode Pallas decodes
# at an interactive rate; structure identical to the full model.
SERVING_CONFIG = model.ModelConfig(
    vocab_size=512, d_model=256, n_layers=2, n_heads=8, head_dim=32,
    d_ff=704, max_context=320, prefill_pad=256, attn_block_s=160,
    prefill_block=128)

GOLDEN_PROMPTS = [
    "explain the theory of relativity in detail",
    "hi there how are you",
    "summarize the following text briefly the quick brown fox jumps",
    "write a function to sort a list in python?",
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(arr) -> dict:
    return {"shape": list(arr.shape), "dtype": str(arr.dtype)}


def _write_hlo(out_dir, name, lowered, inputs, outputs):
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  {name}.hlo.txt ({len(text) / 1e6:.1f} MB)")
    return {"file": f"{name}.hlo.txt", "inputs": inputs, "outputs": outputs}


def _save_params(params, out_dir, subdir):
    os.makedirs(os.path.join(out_dir, subdir), exist_ok=True)
    entries = []
    for name in sorted(params):
        arr = np.asarray(params[name], np.float32)
        rel = f"{subdir}/{name}.bin"
        arr.tofile(os.path.join(out_dir, rel))
        entries.append({"name": name, "file": rel, "shape": list(arr.shape),
                        "dtype": "f32"})
    return entries


def build(out_dir: str, quick: bool = False):
    os.makedirs(out_dir, exist_ok=True)
    cfg = SERVING_CONFIG
    manifest = {
        "version": 1,
        "model": {
            "vocab_size": cfg.vocab_size, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "head_dim": cfg.head_dim, "d_ff": cfg.d_ff,
            "max_context": cfg.max_context, "prefill_pad": cfg.prefill_pad,
            "eos_id": model.EOS_ID, "param_count": cfg.param_count,
            "attn_block_s": cfg.attn_block_s,
            "prefill_block": cfg.prefill_block,
        },
        "artifacts": {},
    }

    # ---- model params ----------------------------------------------------
    print("initializing model params "
          f"({cfg.param_count / 1e6:.1f}M, n_layers={cfg.n_layers})")
    params = model.init_params(jax.random.PRNGKey(42), cfg)
    manifest["params"] = _save_params(params, out_dir, "params")
    param_inputs = [dict(name=f"param:{k}", **_spec(params[k]))
                    for k in sorted(params)]

    # ---- prefill ----------------------------------------------------------
    print("lowering prefill")
    tokens_spec = jax.ShapeDtypeStruct((cfg.prefill_pad,), jnp.int32)
    len_spec = jax.ShapeDtypeStruct((), jnp.int32)
    lowered = jax.jit(functools.partial(model.prefill, cfg=cfg)).lower(
        params, tokens_spec, len_spec)
    manifest["artifacts"]["prefill"] = _write_hlo(
        out_dir, "prefill", lowered,
        param_inputs
        + [{"name": "tokens", "shape": [cfg.prefill_pad], "dtype": "int32"},
           {"name": "length", "shape": [], "dtype": "int32"}],
        [{"name": "first_token", "shape": [], "dtype": "int32"},
         {"name": "kv",
          "shape": [cfg.n_layers, 2, cfg.prefill_pad, cfg.n_heads,
                    cfg.head_dim], "dtype": "f32"}])

    # ---- decode buckets ----------------------------------------------------
    for b in DECODE_BUCKETS:
        print(f"lowering decode_b{b}")
        kv_spec = jax.ShapeDtypeStruct(
            (cfg.n_layers, 2, b, cfg.max_context, cfg.n_heads, cfg.head_dim),
            jnp.float32)
        lens_spec = jax.ShapeDtypeStruct((b,), jnp.int32)
        toks_spec = jax.ShapeDtypeStruct((b,), jnp.int32)
        lowered = jax.jit(functools.partial(model.decode_step, cfg=cfg)).lower(
            params, kv_spec, lens_spec, toks_spec)
        manifest["artifacts"][f"decode_b{b}"] = _write_hlo(
            out_dir, f"decode_b{b}", lowered,
            param_inputs
            + [dict(name="kv", **_spec(kv_spec)),
               {"name": "lens", "shape": [b], "dtype": "int32"},
               {"name": "tokens", "shape": [b], "dtype": "int32"}],
            [{"name": "next_tokens", "shape": [b], "dtype": "int32"},
             dict(name="kv_new", **_spec(kv_spec))])

    # ---- corpus + length model --------------------------------------------
    n = 2000 if quick else CORPUS_N
    n_train = int(n * TRAIN_N / CORPUS_N)
    print(f"generating synthetic ShareGPT corpus ({n} samples)")
    samples = corpus.generate(n)
    corpus.write_jsonl(samples, os.path.join(out_dir, "sharegpt_synth.jsonl"))

    print("training length model "
          f"({n_train} train / {n - n_train} eval samples)")
    lm_params = length_model.train(samples[:n_train],
                                   epochs=8 if quick else 60)
    metrics = length_model.evaluate(lm_params, samples[n_train:])
    print(f"  eval: avg_err={metrics['avg_error']:.1f} tok, "
          f"rate={metrics['avg_error_rate'] * 100:.1f}%, "
          f"acc50={metrics['acc50'] * 100:.1f}%, "
          f"acc100={metrics['acc100'] * 100:.1f}%")
    with open(os.path.join(out_dir, "length_model_eval.json"), "w") as f:
        json.dump(metrics, f, indent=2)

    manifest["length_params"] = _save_params(lm_params, out_dir,
                                             "length_params")
    print("lowering length_model")
    feat_spec = jax.ShapeDtypeStruct((LENGTH_BATCH, length_model.N_FEATURES),
                                     jnp.float32)
    lowered = jax.jit(length_model.predict_lengths).lower(lm_params, feat_spec)
    lm_param_inputs = [dict(name=f"param:{k}", **_spec(lm_params[k]))
                       for k in sorted(lm_params)]
    manifest["artifacts"]["length_model"] = _write_hlo(
        out_dir, "length_model", lowered,
        lm_param_inputs
        + [{"name": "features",
            "shape": [LENGTH_BATCH, length_model.N_FEATURES],
            "dtype": "f32"}],
        [{"name": "pred_lengths", "shape": [LENGTH_BATCH], "dtype": "f32"}])
    manifest["length_model"] = {
        "batch": LENGTH_BATCH,
        "n_features": length_model.N_FEATURES,
        "feature_names": length_model.FEATURE_NAMES,
        "eval": metrics,
        # Golden vectors keep the Rust feature extractor in sync.
        "golden": [{"prompt": p,
                    "features": length_model.extract_features(p),
                    "pred": float(length_model.predict_lengths(
                        lm_params,
                        jnp.asarray([length_model.extract_features(p)],
                                    jnp.float32))[0])}
                   for p in GOLDEN_PROMPTS],
    }
    manifest["corpus"] = {"file": "sharegpt_synth.jsonl", "n": n,
                          "train_n": n_train, "seed": 1234}

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest.json written to {out_dir}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="small corpus + few epochs (CI / tests)")
    args = ap.parse_args()
    build(args.out, quick=args.quick)


if __name__ == "__main__":
    main()
