"""Layer-2 JAX model: a LLaMA-style decoder-only transformer.

This is the compute graph the Rust coordinator serves.  Two entry points
are AOT-lowered (see ``aot.py``):

  * ``prefill(params, tokens[S_pad], length)`` — encode one prompt (the
    prefill phase).  Uses the L1 ``chunked_prefill_attention`` kernel and
    returns the first decoded token plus the prompt's KV cache.
  * ``decode_step(params, kv, lens, tokens[B])`` — one continuous-batching
    decode step for a batch of sequences at heterogeneous positions.  Uses
    the L1 ``decode_attention`` kernel and returns the next token per
    sequence plus the updated cache.

Architecture: RMSNorm, rotary position embeddings, multi-head attention,
SwiGLU MLP, tied input/output embedding — the same block structure as
LLaMA2 (the paper's serving model), scaled down so the CPU PJRT client can
actually serve it (see ``ModelConfig.tiny``).  Parameters are stacked along
a leading layer axis so the layer loop is a ``lax.scan`` (one fused HLO
while-loop rather than n_layers inlined copies).

Python never runs at serving time: these functions exist to be lowered to
HLO text once, at build time.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import chunked_prefill_attention, decode_attention

EOS_ID = 1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Dimensions of the served transformer."""

    vocab_size: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    head_dim: int = 32
    d_ff: int = 704
    max_context: int = 640        # padded KV-cache length (S)
    prefill_pad: int = 512        # padded prompt length for the prefill fn
    rope_theta: float = 10000.0
    attn_block_s: int = 128       # decode kernel KV block ("page") size
    prefill_block: int = 128      # prefill kernel tile size

    @staticmethod
    def tiny() -> "ModelConfig":
        return ModelConfig()

    @property
    def param_count(self) -> int:
        c = self
        per_layer = 4 * c.d_model * c.n_heads * c.head_dim \
            + 3 * c.d_model * c.d_ff + 2 * c.d_model
        return c.vocab_size * c.d_model + c.d_model + c.n_layers * per_layer


# Parameter leaves, all stacked on a leading layer axis where applicable.
# Sorted key order == flattened HLO input order (recorded in the manifest).
PARAM_SHAPES = {
    "attn_norm": lambda c: (c.n_layers, c.d_model),
    "embed": lambda c: (c.vocab_size, c.d_model),
    "final_norm": lambda c: (c.d_model,),
    "mlp_norm": lambda c: (c.n_layers, c.d_model),
    "w_down": lambda c: (c.n_layers, c.d_ff, c.d_model),
    "w_gate": lambda c: (c.n_layers, c.d_model, c.d_ff),
    "w_k": lambda c: (c.n_layers, c.d_model, c.n_heads * c.head_dim),
    "w_o": lambda c: (c.n_layers, c.n_heads * c.head_dim, c.d_model),
    "w_q": lambda c: (c.n_layers, c.d_model, c.n_heads * c.head_dim),
    "w_up": lambda c: (c.n_layers, c.d_model, c.d_ff),
    "w_v": lambda c: (c.n_layers, c.d_model, c.n_heads * c.head_dim),
}


def param_names():
    return sorted(PARAM_SHAPES)


def init_params(key, cfg: ModelConfig):
    """Deterministic scaled-normal init (the 'small real model' weights)."""
    params = {}
    for name in param_names():
        shape = PARAM_SHAPES[name](cfg)
        key, sub = jax.random.split(key)
        if "norm" in name:
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            params[name] = (jax.random.normal(sub, shape, jnp.float32)
                            / jnp.sqrt(fan_in))
    return params


def rmsnorm(x, w, eps=1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x, positions, theta):
    """Rotary embedding. x: [..., H, Dh]; positions broadcastable to x[..., 0, 0]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[..., None, None].astype(jnp.float32) * freqs  # [..., 1, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _layer_stack(params):
    """xs pytree for lax.scan over layers."""
    return {k: params[k] for k in param_names() if k not in ("embed", "final_norm")}


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def prefill(params, tokens, length, cfg: ModelConfig, *, interpret=True):
    """Encode one prompt.

    Args:
      tokens: [prefill_pad] int32, right-padded prompt.
      length: scalar int32, true prompt length (1..prefill_pad).

    Returns:
      first_token: [] int32 — greedy first decoded token.
      kv: [L, 2, prefill_pad, H, Dh] float32 prompt KV cache.
    """
    c = cfg
    s = c.prefill_pad
    x = params["embed"][tokens]                      # [S, D]
    positions = jnp.arange(s, dtype=jnp.int32)

    def layer(x, lp):
        h = rmsnorm(x, lp["attn_norm"])
        q = (h @ lp["w_q"]).reshape(s, c.n_heads, c.head_dim)
        k = (h @ lp["w_k"]).reshape(s, c.n_heads, c.head_dim)
        v = (h @ lp["w_v"]).reshape(s, c.n_heads, c.head_dim)
        q = rope(q, positions, c.rope_theta)
        k = rope(k, positions, c.rope_theta)
        attn = chunked_prefill_attention(
            q, k, v, length, block_q=c.prefill_block, block_k=c.prefill_block,
            interpret=interpret)
        x = x + attn.reshape(s, -1) @ lp["w_o"]
        h = rmsnorm(x, lp["mlp_norm"])
        x = x + (jax.nn.silu(h @ lp["w_gate"]) * (h @ lp["w_up"])) @ lp["w_down"]
        return x, (k, v)

    x, kvs = jax.lax.scan(layer, x, _layer_stack(params))
    kv = jnp.stack(kvs, axis=1)                      # [L, 2, S, H, Dh]
    x = rmsnorm(x, params["final_norm"])
    last = x[length - 1]                             # [D]
    logits = last @ params["embed"].T                # [V]
    return jnp.argmax(logits).astype(jnp.int32), kv


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def decode_step(params, kv, lens, tokens, cfg: ModelConfig, *, interpret=True):
    """One decode step for a batch.

    Args:
      kv: [L, 2, B, S, H, Dh] cache; entries [0:lens[b]) are valid.
      lens: [B] int32 current context length per slot (prompt + decoded).
      tokens: [B] int32 the most recent token per slot (input to this step).

    Returns:
      next_tokens: [B] int32 greedy next tokens.
      kv_new: cache with this step's K/V written at position lens[b].
    """
    c = cfg
    b = tokens.shape[0]
    x = params["embed"][tokens]                      # [B, D]
    positions = lens                                 # new token sits at index lens[b]

    def layer(x, carry):
        lp, kv_l = carry
        h = rmsnorm(x, lp["attn_norm"])
        q = (h @ lp["w_q"]).reshape(b, c.n_heads, c.head_dim)
        k = (h @ lp["w_k"]).reshape(b, c.n_heads, c.head_dim)
        v = (h @ lp["w_v"]).reshape(b, c.n_heads, c.head_dim)
        q = rope(q, positions, c.rope_theta)
        k = rope(k, positions, c.rope_theta)
        # Scatter this step's K/V into the cache at each slot's position.
        k_cache = kv_l[0]                            # [B, S, H, Dh]
        v_cache = kv_l[1]
        onehot = (jnp.arange(c.max_context)[None, :] == positions[:, None])
        k_cache = jnp.where(onehot[:, :, None, None], k[:, None], k_cache)
        v_cache = jnp.where(onehot[:, :, None, None], v[:, None], v_cache)
        attn = decode_attention(q, k_cache, v_cache, lens + 1,
                                block_s=c.attn_block_s, interpret=interpret)
        x = x + attn.reshape(b, -1) @ lp["w_o"]
        h = rmsnorm(x, lp["mlp_norm"])
        x = x + (jax.nn.silu(h @ lp["w_gate"]) * (h @ lp["w_up"])) @ lp["w_down"]
        return x, jnp.stack([k_cache, v_cache])

    x, kv_new = jax.lax.scan(layer, x, (_layer_stack(params), kv))
    x = rmsnorm(x, params["final_norm"])
    logits = x @ params["embed"].T                   # [B, V]
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), kv_new


# ---------------------------------------------------------------------------
# Reference serving loop (used by tests; Rust reimplements this loop)
# ---------------------------------------------------------------------------


def generate_greedy(params, prompt_tokens, max_new, cfg: ModelConfig,
                    *, interpret=True):
    """Single-sequence greedy generation: prefill + decode loop."""
    c = cfg
    pad = jnp.zeros(c.prefill_pad, jnp.int32)
    length = len(prompt_tokens)
    toks = pad.at[:length].set(jnp.asarray(prompt_tokens, jnp.int32))
    first, kv_prompt = prefill(params, toks, jnp.int32(length), cfg,
                               interpret=interpret)
    # Place the prompt cache into a batch=1 serving cache.
    kv = jnp.zeros((c.n_layers, 2, 1, c.max_context, c.n_heads, c.head_dim),
                   jnp.float32)
    kv = kv.at[:, :, 0, :c.prefill_pad].set(kv_prompt)
    out = [int(first)]
    lens = jnp.asarray([length], jnp.int32)
    tok = jnp.asarray([int(first)], jnp.int32)
    for _ in range(max_new - 1):
        if out[-1] == EOS_ID:
            break
        tok, kv = decode_step(params, kv, lens, tok, cfg, interpret=interpret)
        lens = lens + 1
        out.append(int(tok[0]))
    return out
