"""Synthetic ShareGPT-like corpus (the paper's dataset substitute).

The real evaluation uses 52k ShareGPT conversations; we cannot ship those,
so this module generates a deterministic synthetic corpus whose *scheduling-
relevant* marginals match published ShareGPT statistics: heavy-tailed
lognormal prompt/response token lengths (mean prompt ~160 tokens, mean
response ~240 tokens), and — crucially for Block — a strong, learnable
dependence of response length on prompt *context* (an "explain ..." prompt
yields a long answer, "summarize ..." a short one).  That dependence is
exactly the signal the paper's RoBERTa length tagger exploits.

The corpus is written once at build time to ``artifacts/sharegpt_synth.jsonl``
(prompt text + true token lengths) and is the single source of truth shared
by the Python length-model trainer and the Rust Table-1 / tagger / serving
code — no cross-language RNG matching required.
"""

from __future__ import annotations

import json
import math

# (name, weight, templates, filler-word range, response lognormal (mu, sigma))
# Response means: greeting 20, qa 80, explain 400, code 250, summarize 60,
# creative 500, translate 90, list 120 tokens.
CATEGORIES = [
    ("greeting", 8, [
        "hi there how are you doing today",
        "hello good morning nice to meet you",
        "hey whats up",
    ], (0, 6), (math.log(20.0), 0.35)),
    ("qa", 22, [
        "what is {} and who discovered it",
        "what is the capital of {}",
        "when did {} happen and why",
        "who invented {} and what year was it",
    ], (2, 18), (math.log(80.0), 0.35)),
    ("explain", 18, [
        "explain the theory of {} in detail",
        "can you explain how {} works and describe the mechanism in detail",
        "describe {} comprehensively and explain why it matters",
    ], (2, 20), (math.log(400.0), 0.30)),
    ("code", 14, [
        "write a function to {} in python",
        "implement a program that can {} efficiently",
        "write code to {} and add tests",
    ], (3, 24), (math.log(250.0), 0.35)),
    ("summarize", 12, [
        "summarize the following text briefly {}",
        "give me a short tl;dr of this document {}",
    ], (80, 420), (math.log(60.0), 0.30)),
    ("creative", 10, [
        "write a story about {}",
        "write a long creative poem about {}",
        "write an essay about {} with comprehensive detail",
    ], (2, 14), (math.log(500.0), 0.40)),
    ("translate", 8, [
        "translate the following to french {}",
        "translate this text into german {}",
    ], (40, 260), (math.log(90.0), 0.30)),
    ("list", 8, [
        "list ten interesting facts about {}",
        "list the main reasons why {} how many are there",
    ], (2, 12), (math.log(120.0), 0.30)),
]

FILLER = ("the quick brown fox jumps over a lazy dog while autumn leaves "
          "drift across the quiet river and distant mountains fade into "
          "violet evening light as travelers recall half forgotten stories "
          "about science history art music economics physics biology "
          "medicine law engineering philosophy language culture trade "
          "climate energy transport memory logic networks systems data "
          "models markets cities oceans forests deserts islands empires "
          "inventions discoveries journeys experiments equations theories").split()

MAX_MODEL_LEN = 2048   # vLLM max_model_len analogue (prompt + response)
MIN_RESPONSE = 4


class SplitMix64:
    """Deterministic 64-bit PRNG (same algorithm as rust/src/util/rng.rs)."""

    MASK = (1 << 64) - 1

    def __init__(self, seed: int):
        self.state = seed & self.MASK

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & self.MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & self.MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & self.MASK
        return z ^ (z >> 31)

    def next_f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def randint(self, lo: int, hi: int) -> int:
        """Uniform integer in [lo, hi]."""
        return lo + self.next_u64() % (hi - lo + 1)

    def lognormal(self, mu: float, sigma: float) -> float:
        # Box-Muller
        u1 = max(self.next_f64(), 1e-12)
        u2 = self.next_f64()
        z = math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)
        return math.exp(mu + sigma * z)


def prompt_token_len(text: str) -> int:
    """Prompt length in 'tokens' — a simple chars/4 model shared with Rust
    (`workload::tokenizer::approx_token_len`)."""
    return max(4, (len(text) + 3) // 4)


def sample(rng: SplitMix64) -> dict:
    total_w = sum(c[1] for c in CATEGORIES)
    r = rng.randint(0, total_w - 1)
    for name, w, templates, (fmin, fmax), (mu, sigma) in CATEGORIES:
        if r < w:
            break
        r -= w
    tmpl = templates[rng.randint(0, len(templates) - 1)]
    n_fill = rng.randint(fmin, fmax)
    words = [FILLER[rng.randint(0, len(FILLER) - 1)] for _ in range(n_fill)]
    prompt = tmpl.format(" ".join(words)) if "{}" in tmpl else tmpl
    p_tokens = prompt_token_len(prompt)
    max_resp = max(MIN_RESPONSE, MAX_MODEL_LEN - p_tokens)
    resp = int(round(rng.lognormal(mu, sigma)))
    resp = min(max(resp, MIN_RESPONSE), max_resp)
    return {
        "category": name,
        "prompt": prompt,
        "prompt_tokens": p_tokens,
        "response_tokens": resp,
    }


def generate(n: int, seed: int = 1234) -> list[dict]:
    rng = SplitMix64(seed)
    return [sample(rng) for _ in range(n)]


def write_jsonl(samples, path):
    with open(path, "w") as f:
        for s in samples:
            f.write(json.dumps(s) + "\n")


if __name__ == "__main__":
    import sys

    n = int(sys.argv[1]) if len(sys.argv) > 1 else 50000
    out = sys.argv[2] if len(sys.argv) > 2 else "artifacts/sharegpt_synth.jsonl"
    samples = generate(n)
    write_jsonl(samples, out)
    mean_p = sum(s["prompt_tokens"] for s in samples) / n
    mean_r = sum(s["response_tokens"] for s in samples) / n
    print(f"wrote {n} samples to {out}; mean prompt={mean_p:.1f} "
          f"mean response={mean_r:.1f} tokens")
