"""Response-length regressor — the paper's RoBERTa-125M stand-in.

The paper fine-tunes a RoBERTa-base regression model to predict a request's
response length from its prompt (Table 1: 24.4% average error rate, Acc-50
69.9%, Acc-100 77.2%).  Shipping/fine-tuning RoBERTa is out of scope here,
so we train a small MLP over 16 hand-crafted prompt features — the features
capture exactly the "context" signal the paper's motivation cites (an
"explain ..." prompt is short but yields a long answer; "summarize ..." the
reverse).

Feature extraction (``extract_features``) is mirrored byte-for-byte in Rust
(`tagger/features.rs`); golden vectors in the manifest keep the two in sync.
The trained model is AOT-lowered to HLO (``aot.py``) and served by the Rust
tagger through PJRT — prediction happens on the request path with zero
Python.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

N_FEATURES = 16
KEYWORDS = [
    ("explain", "describe"),
    ("write",),
    ("story", "poem", "essay"),
    ("code", "function", "implement", "program"),
    ("summarize", "tl;dr", "brief"),
    ("list", "enumerate"),
    ("translate",),
    ("what",),
    ("how",),
    ("why",),
    ("short", "one sentence"),
    ("detail", "comprehensive", "long"),
]

FEATURE_NAMES = (
    ["chars", "words", "qmarks", "avg_word_len"]
    + ["kw_" + kws[0] for kws in KEYWORDS]
)
assert len(FEATURE_NAMES) == N_FEATURES


def extract_features(text: str) -> list[float]:
    """16 normalized features of a prompt.  Mirrored in Rust — keep in sync
    with `rust/src/tagger/features.rs` (golden-tested via the manifest)."""
    t = text.lower()
    words = t.split()
    n_chars = len(t)
    n_words = len(words)
    avg_wl = (sum(len(w) for w in words) / n_words) if n_words else 0.0
    feats = [
        min(n_chars, 2048) / 2048.0,
        min(n_words, 400) / 400.0,
        min(t.count("?"), 4) / 4.0,
        min(avg_wl, 12.0) / 12.0,
    ]
    for kws in KEYWORDS:
        feats.append(1.0 if any(k in t for k in kws) else 0.0)
    return feats


# ---------------------------------------------------------------------------
# Model: MLP 16 -> 64 -> 64 -> 1 predicting log1p(response_tokens)
# ---------------------------------------------------------------------------

HIDDEN = 64


def init_mlp(key):
    k1, k2, k3 = jax.random.split(key, 3)
    s1, s2, s3 = (N_FEATURES ** -0.5), (HIDDEN ** -0.5), (HIDDEN ** -0.5)
    return {
        "w1": jax.random.normal(k1, (N_FEATURES, HIDDEN)) * s1,
        "b1": jnp.zeros((HIDDEN,)),
        "w2": jax.random.normal(k2, (HIDDEN, HIDDEN)) * s2,
        "b2": jnp.zeros((HIDDEN,)),
        "w3": jax.random.normal(k3, (HIDDEN, 1)) * s3,
        "b3": jnp.zeros((1,)),
    }


def mlp_log_len(params, x):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    h = jax.nn.relu(h @ params["w2"] + params["b2"])
    return (h @ params["w3"] + params["b3"])[..., 0]


def predict_lengths(params, x):
    """[N, 16] features -> [N] predicted response tokens (the AOT entry)."""
    return jnp.maximum(jnp.expm1(mlp_log_len(params, x)), 1.0)


def _loss(params, x, y_log):
    return jnp.mean(jnp.square(mlp_log_len(params, x) - y_log))


@jax.jit
def _adam_step(params, m, v, t, x, y_log, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    g = jax.grad(_loss)(params, x, y_log)
    m = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
    v = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
    mh = jax.tree.map(lambda a: a / (1 - b1 ** t), m)
    vh = jax.tree.map(lambda a: a / (1 - b2 ** t), v)
    params = jax.tree.map(lambda p, a, b: p - lr * a / (jnp.sqrt(b) + eps),
                          params, mh, vh)
    return params, m, v


def train(samples, *, epochs: int = 60, batch: int = 1024, seed: int = 7,
          log=print):
    """Train on corpus samples (list of dicts with prompt/response_tokens)."""
    x = np.asarray([extract_features(s["prompt"]) for s in samples],
                   np.float32)
    y = np.log1p(np.asarray([s["response_tokens"] for s in samples],
                            np.float32))
    params = init_mlp(jax.random.PRNGKey(seed))
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    n = len(x)
    rng = np.random.default_rng(seed)
    t = 0
    for ep in range(epochs):
        perm = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            idx = perm[i:i + batch]
            t += 1
            params, m, v = _adam_step(params, m, v, t,
                                      jnp.asarray(x[idx]), jnp.asarray(y[idx]))
        if ep % 20 == 0 or ep == epochs - 1:
            log(f"  length-model epoch {ep}: loss="
                f"{float(_loss(params, jnp.asarray(x), jnp.asarray(y))):.4f}")
    return params


def evaluate(params, samples):
    """Table-1 metrics: avg error (tokens), avg error rate, Acc-50, Acc-100."""
    x = jnp.asarray([extract_features(s["prompt"]) for s in samples],
                    jnp.float32)
    y = np.asarray([s["response_tokens"] for s in samples], np.float64)
    pred = np.asarray(predict_lengths(params, x), np.float64)
    err = np.abs(pred - y)
    return {
        "avg_error": float(err.mean()),
        "avg_error_rate": float((err / np.maximum(y, 1.0)).mean()),
        "acc50": float((err < 50).mean()),
        "acc100": float((err < 100).mean()),
    }
