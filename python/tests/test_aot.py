"""AOT pipeline: artifacts, manifest schema, golden feature vectors."""

import json
import os

import numpy as np
import pytest

from compile import aot, length_model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.build(out, quick=True)
    return out


def _manifest(built):
    with open(os.path.join(built, "manifest.json")) as f:
        return json.load(f)


def test_all_artifact_files_exist(built):
    man = _manifest(built)
    for art in man["artifacts"].values():
        path = os.path.join(built, art["file"])
        assert os.path.exists(path), path
        head = open(path).read(200)
        assert "HloModule" in head, f"{path} is not HLO text"
    for p in man["params"] + man["length_params"]:
        full = os.path.join(built, p["file"])
        expected = int(np.prod(p["shape"])) * 4
        assert os.path.getsize(full) == expected


def test_manifest_model_block(built):
    m = _manifest(built)["model"]
    for key in ("vocab_size", "d_model", "n_layers", "n_heads", "head_dim",
                "max_context", "prefill_pad", "eos_id"):
        assert key in m
    assert m["max_context"] % m["attn_block_s"] == 0
    assert m["prefill_pad"] % m["prefill_block"] == 0


def test_manifest_decode_buckets(built):
    arts = _manifest(built)["artifacts"]
    for b in aot.DECODE_BUCKETS:
        a = arts[f"decode_b{b}"]
        kv = next(i for i in a["inputs"] if i["name"] == "kv")
        assert kv["shape"][2] == b
        out = next(o for o in a["outputs"] if o["name"] == "next_tokens")
        assert out["shape"] == [b]


def test_param_inputs_sorted_and_first(built):
    """Rust feeds params first, in sorted-key order — pin that contract."""
    arts = _manifest(built)["artifacts"]
    for name, a in arts.items():
        pnames = [i["name"] for i in a["inputs"]
                  if i["name"].startswith("param:")]
        assert pnames == sorted(pnames)
        n = len(pnames)
        assert all(i["name"].startswith("param:")
                   for i in a["inputs"][:n])


def test_golden_features_match(built):
    """The manifest golden vectors equal a fresh extraction — this is the
    cross-language contract the Rust tagger tests against."""
    lm = _manifest(built)["length_model"]
    assert lm["feature_names"] == length_model.FEATURE_NAMES
    for g in lm["golden"]:
        assert g["features"] == length_model.extract_features(g["prompt"])
        assert g["pred"] >= 1.0


def test_corpus_file(built):
    man = _manifest(built)
    lines = open(os.path.join(built, man["corpus"]["file"])).readlines()
    assert len(lines) == man["corpus"]["n"]
    rec = json.loads(lines[0])
    assert {"category", "prompt", "prompt_tokens",
            "response_tokens"} <= set(rec)
