"""Length regressor: feature extraction, training signal, Table-1 metrics."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from compile import corpus, length_model as L


def test_feature_vector_shape_and_range():
    for s in corpus.generate(500):
        f = L.extract_features(s["prompt"])
        assert len(f) == L.N_FEATURES
        assert all(0.0 <= x <= 1.0 for x in f)


@hypothesis.given(st.text(max_size=300))
@hypothesis.settings(max_examples=50, deadline=None)
def test_features_total_on_arbitrary_text(text):
    f = L.extract_features(text)
    assert len(f) == L.N_FEATURES
    assert all(0.0 <= x <= 1.0 for x in f)


def test_feature_keywords():
    f = L.extract_features("please EXPLAIN this in detail")
    names = L.FEATURE_NAMES
    assert f[names.index("kw_explain")] == 1.0
    assert f[names.index("kw_detail")] == 1.0
    assert f[names.index("kw_translate")] == 0.0


def test_empty_prompt():
    f = L.extract_features("")
    assert f == [0.0] * L.N_FEATURES


def test_training_reduces_loss_and_learns_signal():
    samples = corpus.generate(4000, seed=3)
    params = L.train(samples[:3200], epochs=30, batch=256,
                     log=lambda s: None)
    m = L.evaluate(params, samples[3200:])
    # Must clearly beat the no-context baseline (predicting the global
    # mean gives ~150%+ error rate on this mixture).
    assert m["avg_error_rate"] < 0.8, m
    assert m["acc50"] > 0.35, m
    # Long-context prompts predicted longer than short-context prompts.
    x_long = jnp.asarray([L.extract_features("write a long creative poem about stars")],
                         jnp.float32)
    x_short = jnp.asarray([L.extract_features("hi there how are you doing today")],
                          jnp.float32)
    assert float(L.predict_lengths(params, x_long)[0]) > \
        float(L.predict_lengths(params, x_short)[0])


def test_predict_lengths_positive():
    params = L.init_mlp(jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.default_rng(0).random((8, L.N_FEATURES)),
                    jnp.float32)
    out = L.predict_lengths(params, x)
    assert out.shape == (8,)
    assert bool((out >= 1.0).all())
