"""Synthetic ShareGPT corpus: determinism and distributional sanity."""

import math

from compile import corpus


def test_deterministic():
    a = corpus.generate(200, seed=99)
    b = corpus.generate(200, seed=99)
    assert a == b


def test_seed_changes_output():
    assert corpus.generate(50, seed=1) != corpus.generate(50, seed=2)


def test_max_model_len_invariant():
    for s in corpus.generate(2000):
        assert s["prompt_tokens"] + s["response_tokens"] <= corpus.MAX_MODEL_LEN
        assert s["response_tokens"] >= corpus.MIN_RESPONSE
        assert s["prompt_tokens"] == corpus.prompt_token_len(s["prompt"])


def test_marginals_in_band():
    samples = corpus.generate(20000)
    mp = sum(s["prompt_tokens"] for s in samples) / len(samples)
    mr = sum(s["response_tokens"] for s in samples) / len(samples)
    # ShareGPT-like bands (see DESIGN.md substitutions table).
    assert 60 <= mp <= 220, mp
    assert 150 <= mr <= 360, mr


def test_category_means_ordered():
    """The context signal: explain/creative are long, greeting/summarize short."""
    samples = corpus.generate(20000)
    by_cat = {}
    for s in samples:
        by_cat.setdefault(s["category"], []).append(s["response_tokens"])
    mean = {c: sum(v) / len(v) for c, v in by_cat.items()}
    assert mean["creative"] > mean["explain"] > mean["code"] > mean["qa"]
    assert mean["qa"] > mean["summarize"] > mean["greeting"]


def test_heavy_tail():
    samples = corpus.generate(20000)
    resp = sorted(s["response_tokens"] for s in samples)
    p50 = resp[len(resp) // 2]
    p99 = resp[int(len(resp) * 0.99)]
    assert p99 > 4 * p50, (p50, p99)


def test_splitmix64_reference_vector():
    """Pin the PRNG to SplitMix64 reference output (same constants as the
    Rust util::rng implementation)."""
    r = corpus.SplitMix64(1234)
    first = [r.next_u64() for _ in range(3)]
    r2 = corpus.SplitMix64(1234)
    assert [r2.next_u64() for _ in range(3)] == first
    assert all(0 <= v < 2**64 for v in first)
    f = corpus.SplitMix64(7).next_f64()
    assert 0.0 <= f < 1.0


def test_lognormal_moments():
    r = corpus.SplitMix64(5)
    mu, sigma = math.log(100.0), 0.3
    xs = [r.lognormal(mu, sigma) for _ in range(20000)]
    mean = sum(xs) / len(xs)
    expected = math.exp(mu + sigma * sigma / 2)
    assert abs(mean - expected) / expected < 0.05
