"""L2 model correctness: prefill/decode consistency and serving invariants."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels.ref import causal_attention_ref

# Small config so interpret-mode tests stay fast.
CFG = M.ModelConfig(vocab_size=64, d_model=32, n_layers=2, n_heads=2,
                    head_dim=16, d_ff=64, max_context=128, prefill_pad=64,
                    attn_block_s=64, prefill_block=32)


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(0), CFG)


def ref_forward(params, tokens, cfg):
    """Plain-jnp full forward over a whole sequence (no kernels, no cache):
    the oracle for both prefill and incremental decode."""
    s = len(tokens)
    x = params["embed"][jnp.asarray(tokens)]
    positions = jnp.arange(s, dtype=jnp.int32)
    for l in range(cfg.n_layers):
        h = M.rmsnorm(x, params["attn_norm"][l])
        q = (h @ params["w_q"][l]).reshape(s, cfg.n_heads, cfg.head_dim)
        k = (h @ params["w_k"][l]).reshape(s, cfg.n_heads, cfg.head_dim)
        v = (h @ params["w_v"][l]).reshape(s, cfg.n_heads, cfg.head_dim)
        q = M.rope(q, positions, cfg.rope_theta)
        k = M.rope(k, positions, cfg.rope_theta)
        attn = causal_attention_ref(q, k, v, s)
        x = x + attn.reshape(s, -1) @ params["w_o"][l]
        h = M.rmsnorm(x, params["mlp_norm"][l])
        x = x + (jax.nn.silu(h @ params["w_gate"][l])
                 * (h @ params["w_up"][l])) @ params["w_down"][l]
    x = M.rmsnorm(x, params["final_norm"])
    return x @ params["embed"].T          # [S, V] logits


def test_param_shapes_and_count(params):
    total = sum(int(np.prod(p.shape)) for p in params.values())
    assert total == CFG.param_count
    for name, fn in M.PARAM_SHAPES.items():
        assert params[name].shape == fn(CFG)


def test_prefill_matches_ref_forward(params):
    prompt = [3, 17, 5, 40, 9, 22, 7]
    toks = jnp.zeros(CFG.prefill_pad, jnp.int32).at[:len(prompt)].set(
        jnp.asarray(prompt))
    first, kv = M.prefill(params, toks, jnp.int32(len(prompt)), CFG)
    logits = ref_forward(params, prompt, CFG)
    assert int(first) == int(jnp.argmax(logits[-1]))
    assert kv.shape == (CFG.n_layers, 2, CFG.prefill_pad, CFG.n_heads,
                        CFG.head_dim)


def test_decode_step_matches_ref_forward(params):
    """prefill + one decode step == full forward over prompt+token."""
    prompt = [3, 17, 5, 40, 9]
    toks = jnp.zeros(CFG.prefill_pad, jnp.int32).at[:len(prompt)].set(
        jnp.asarray(prompt))
    first, kvp = M.prefill(params, toks, jnp.int32(len(prompt)), CFG)

    kv = jnp.zeros((CFG.n_layers, 2, 1, CFG.max_context, CFG.n_heads,
                    CFG.head_dim), jnp.float32)
    kv = kv.at[:, :, 0, :CFG.prefill_pad].set(kvp)
    nxt, _ = M.decode_step(params, kv, jnp.asarray([len(prompt)], jnp.int32),
                           jnp.asarray([int(first)], jnp.int32), CFG)

    logits = ref_forward(params, prompt + [int(first)], CFG)
    assert int(nxt[0]) == int(jnp.argmax(logits[-1]))


def test_multi_step_decode_matches_ref(params):
    """Three incremental decode steps track the no-cache reference."""
    prompt = [1, 2, 3, 4]
    seq = M.generate_greedy(params, prompt, 4, CFG)
    cur = list(prompt)
    for tok in seq:
        logits = ref_forward(params, cur, CFG)
        assert tok == int(jnp.argmax(logits[-1]))
        cur.append(tok)
        if tok == M.EOS_ID:
            break


def test_decode_batch_slots_independent(params):
    """A batched decode step gives each slot the same result as running it
    alone — continuous batching must not couple requests."""
    b = 4
    rng = np.random.default_rng(0)
    kv = jnp.asarray(rng.standard_normal(
        (CFG.n_layers, 2, b, CFG.max_context, CFG.n_heads, CFG.head_dim)),
        jnp.float32) * 0.1
    lens = jnp.asarray([3, 9, 27, 64], jnp.int32)
    toks = jnp.asarray([5, 6, 7, 8], jnp.int32)
    nt_full, kv_full = M.decode_step(params, kv, lens, toks, CFG)
    for i in range(b):
        nt_i, kv_i = M.decode_step(params, kv[:, :, i:i+1], lens[i:i+1],
                                   toks[i:i+1], CFG)
        assert int(nt_full[i]) == int(nt_i[0])
        np.testing.assert_allclose(np.asarray(kv_full[:, :, i]),
                                   np.asarray(kv_i[:, :, 0]), atol=1e-5)


def test_decode_writes_cache_at_position(params):
    b = 2
    kv = jnp.zeros((CFG.n_layers, 2, b, CFG.max_context, CFG.n_heads,
                    CFG.head_dim), jnp.float32)
    lens = jnp.asarray([5, 10], jnp.int32)
    toks = jnp.asarray([3, 4], jnp.int32)
    _, kv2 = M.decode_step(params, kv, lens, toks, CFG)
    for i, ln in enumerate([5, 10]):
        written = np.asarray(kv2[:, :, i, ln])
        assert np.abs(written).max() > 0, "new K/V row must be written"
        untouched = np.asarray(kv2[:, :, i, ln + 1:])
        assert np.abs(untouched).max() == 0, "rows beyond position untouched"


def test_generate_deterministic(params):
    a = M.generate_greedy(params, [9, 8, 7], 5, CFG)
    b = M.generate_greedy(params, [9, 8, 7], 5, CFG)
    assert a == b and len(a) <= 5
