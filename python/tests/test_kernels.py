"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes, lengths and dtypes; fixed edge cases pin the
boundaries (len=1, len=S, single block, many blocks, chunked q_offset).
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import chunked_prefill_attention, decode_attention
from compile.kernels.ref import causal_attention_ref, decode_attention_ref

hypothesis.settings.register_profile(
    "kernels", max_examples=25, deadline=None,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("kernels")


def rand(rng, shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


def assert_close(a, b, dtype=jnp.float32):
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------


@hypothesis.given(
    b=st.integers(1, 8),
    nb=st.integers(1, 4),
    block_s=st.sampled_from([32, 64, 128]),
    h=st.sampled_from([1, 2, 8]),
    dh=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_matches_ref(b, nb, block_s, h, dh, seed):
    rng = np.random.default_rng(seed)
    s = nb * block_s
    q = rand(rng, (b, h, dh))
    k = rand(rng, (b, s, h, dh))
    v = rand(rng, (b, s, h, dh))
    lens = jnp.asarray(rng.integers(1, s + 1, size=b), jnp.int32)
    out = decode_attention(q, k, v, lens, block_s=block_s)
    assert_close(out, decode_attention_ref(q, k, v, lens))


@pytest.mark.parametrize("lens", [[1, 1, 1, 1], [256, 256, 256, 256],
                                  [1, 128, 129, 256]])
def test_decode_boundary_lengths(lens):
    rng = np.random.default_rng(0)
    b, s, h, dh = 4, 256, 4, 16
    q, k, v = rand(rng, (b, h, dh)), rand(rng, (b, s, h, dh)), rand(rng, (b, s, h, dh))
    lens = jnp.asarray(lens, jnp.int32)
    out = decode_attention(q, k, v, lens, block_s=128)
    assert_close(out, decode_attention_ref(q, k, v, lens))


def test_decode_len1_ignores_rest_of_cache():
    """With len=1 the output must equal v[0] exactly (softmax over 1 entry),
    regardless of garbage in the rest of the cache."""
    rng = np.random.default_rng(3)
    b, s, h, dh = 2, 128, 2, 8
    q = rand(rng, (b, h, dh))
    k = rand(rng, (b, s, h, dh))
    v = rand(rng, (b, s, h, dh))
    # poison the masked region
    v = v.at[:, 1:].set(1e9)
    k = k.at[:, 1:].set(1e9)
    lens = jnp.ones(b, jnp.int32)
    out = decode_attention(q, k, v, lens, block_s=64)
    assert_close(out, v[:, 0])


def test_decode_invalid_block_raises():
    rng = np.random.default_rng(0)
    q, k, v = rand(rng, (1, 2, 8)), rand(rng, (1, 100, 2, 8)), rand(rng, (1, 100, 2, 8))
    with pytest.raises(ValueError):
        decode_attention(q, k, v, jnp.ones(1, jnp.int32), block_s=64)


def test_decode_batch_independence():
    """Each slot's output depends only on its own q/k/v/len."""
    rng = np.random.default_rng(9)
    b, s, h, dh = 4, 128, 2, 16
    q, k, v = rand(rng, (b, h, dh)), rand(rng, (b, s, h, dh)), rand(rng, (b, s, h, dh))
    lens = jnp.asarray([5, 70, 128, 1], jnp.int32)
    full = decode_attention(q, k, v, lens, block_s=64)
    for i in range(b):
        solo = decode_attention(q[i:i+1], k[i:i+1], v[i:i+1], lens[i:i+1],
                                block_s=64)
        assert_close(full[i], solo[0])


# ---------------------------------------------------------------------------
# chunked_prefill_attention
# ---------------------------------------------------------------------------


@hypothesis.given(
    nq=st.integers(1, 3),
    nk=st.integers(1, 3),
    block=st.sampled_from([32, 64]),
    h=st.sampled_from([1, 4]),
    dh=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_prefill_matches_ref(nq, nk, block, h, dh, seed):
    hypothesis.assume(nk >= nq)   # k covers at least the q range
    rng = np.random.default_rng(seed)
    sq, sk = nq * block, nk * block
    q = rand(rng, (sq, h, dh))
    k = rand(rng, (sk, h, dh))
    v = rand(rng, (sk, h, dh))
    length = int(rng.integers(1, sk + 1))
    out = chunked_prefill_attention(q, k, v, length, block_q=block,
                                    block_k=block)
    assert_close(out, causal_attention_ref(q, k, v, length))


def test_prefill_chunked_equals_full():
    """Prefilling in two chunks (q_offset) must equal one full prefill —
    the invariant Sarathi-style chunked prefill rests on."""
    rng = np.random.default_rng(11)
    s, h, dh, blk = 256, 4, 16, 64
    q = rand(rng, (s, h, dh))
    k = rand(rng, (s, h, dh))
    v = rand(rng, (s, h, dh))
    full = chunked_prefill_attention(q, k, v, s, block_q=blk, block_k=blk)
    half = s // 2
    c1 = chunked_prefill_attention(q[:half], k[:half], v[:half], half,
                                   block_q=blk, block_k=blk)
    c2 = chunked_prefill_attention(q[half:], k, v, s, q_offset=half,
                                   block_q=blk, block_k=blk)
    assert_close(jnp.concatenate([c1, c2]), full)


def test_prefill_first_row_is_v0():
    rng = np.random.default_rng(5)
    s, h, dh = 64, 2, 8
    q, k, v = rand(rng, (s, h, dh)), rand(rng, (s, h, dh)), rand(rng, (s, h, dh))
    out = chunked_prefill_attention(q, k, v, s, block_q=32, block_k=32)
    assert_close(out[0], v[0])


def test_prefill_padding_rows_are_finite():
    """Query rows beyond `length` are padding; they must not produce NaNs
    (they feed later matmuls before being masked at the logits stage)."""
    rng = np.random.default_rng(6)
    s, h, dh = 128, 2, 8
    q, k, v = rand(rng, (s, h, dh)), rand(rng, (s, h, dh)), rand(rng, (s, h, dh))
    out = chunked_prefill_attention(q, k, v, 40, block_q=64, block_k=64)
    assert bool(jnp.isfinite(out).all())


def test_prefill_invalid_shapes_raise():
    rng = np.random.default_rng(0)
    q = rand(rng, (100, 2, 8))
    with pytest.raises(ValueError):
        chunked_prefill_attention(q, q, q, 10, block_q=64, block_k=64)
