#!/usr/bin/env python3
"""elasticity-smoke: kill, restart, and re-admit real instance daemons.

Brings up 3 sim-clock instance daemons + 1 gateway (``block serve``) on
loopback and drives the wire side of the elasticity lifecycle:

* phase A — healthy traffic lands on all 3 instances;
* phase B — one daemon is SIGKILLed between batches; every subsequent
  request still returns 200 (bounce -> redispatch), i.e. no accepted
  request is dropped, and nothing lands on the dead slot;
* phase C — the daemon is restarted on the same port and the gateway
  re-admits it (health probe or status re-sync); the dispatch split
  rebalances onto the rejoined instance;
* manifest — ``POST /manifest`` removes the instance under live traffic
  (drain -> retire, no new dispatches) and a second update re-adds it
  (retired -> backup -> probed -> active);
* telemetry — ``GET /status`` exposes the live active set and the
  lifecycle transition timeline; ``GET /healthz`` answers on instances;
  ``GET /metrics`` tracks the slot-state gauge through the episode.

Usage: elasticity_smoke.py [--scheduler block|min-qpm] [--bin PATH]
"""

import argparse
import json
import subprocess
import sys
import tempfile

from smoke_common import (fire_batch, http, scrape_metrics, shutdown_all,
                          wait_for_instance, wait_healthy)

BASE_PORT = 18800
N_INSTANCES = 3
VICTIM = 2


def spawn_instance(args, mf_name, index):
    return subprocess.Popen(
        [args.bin, "serve", "--role", "instance",
         "--manifest", mf_name, "--index", str(index)])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scheduler", default="block")
    ap.add_argument("--bin", default="target/release/block")
    ap.add_argument("--base-port", type=int, default=BASE_PORT)
    args = ap.parse_args()

    gw_addr = f"127.0.0.1:{args.base_port}"
    inst_addrs = [f"127.0.0.1:{args.base_port + 1 + i}"
                  for i in range(N_INSTANCES)]
    manifest = {
        "schema": "block-cluster/v1",
        "cluster": {
            "scheduler": args.scheduler,
            "frontends": 2,
            "sync_interval": 0.25,
            "n_instances": N_INSTANCES,
        },
        "instances": inst_addrs,
        "gateways": [gw_addr],
        "backend": "sim",
        "clock": "wall",
        "time_scale": 50.0,
    }
    mf = tempfile.NamedTemporaryFile("w", suffix=".json", delete=False)
    json.dump(manifest, mf)
    mf.close()

    procs = {}
    total_ok = 0
    try:
        for i in range(N_INSTANCES):
            procs[i] = spawn_instance(args, mf.name, i)
        procs["gw"] = subprocess.Popen(
            [args.bin, "serve", "--role", "gateway",
             "--manifest", mf.name, "--index", "0"])
        for addr in inst_addrs + [gw_addr]:
            wait_healthy(addr)

        # The O(1) liveness probe answers on every instance.
        for addr in inst_addrs:
            status, body = http("GET", addr, "/healthz", timeout=2)
            assert status == 200 and body.get("ok"), (addr, body)

        # Phase A: healthy traffic reaches all instances.
        a = fire_batch(gw_addr, 12, "phase-a")
        total_ok += 12
        split_a = [a.count(i) for i in range(N_INSTANCES)]
        print(f"phase A split: {split_a}")
        assert all(n >= 1 for n in split_a), f"skewed: {split_a}"
        gm, _ = scrape_metrics(gw_addr)
        assert gm[("block_slots", (("state", "active"),))] == N_INSTANCES

        # Phase B: kill one daemon between batches; traffic must keep
        # completing on the survivors with zero dropped requests.
        procs[VICTIM].kill()
        procs[VICTIM].wait()
        b = fire_batch(gw_addr, 12, "phase-b")
        total_ok += 12
        assert all(i != VICTIM for i in b), \
            f"dispatch landed on the dead instance: {b}"
        print(f"phase B split: {[b.count(i) for i in range(N_INSTANCES)]}")

        # The gateway exports the lifecycle vocabulary.
        _, gst = http("GET", gw_addr, "/status")
        assert len(gst["active_set"]) == N_INSTANCES, gst["active_set"]
        assert isinstance(gst["lifecycle"], list)
        for ev in gst["lifecycle"]:
            for field in ("time", "instance", "state", "cause"):
                assert field in ev, ev
        # The slot-state gauge mirrors the active set.
        gm, _ = scrape_metrics(gw_addr)
        assert gm[("block_slots", (("state", "active"),))] < N_INSTANCES

        # Phase C: restart the daemon on the same port; the gateway
        # re-admits it and the split rebalances.
        procs[VICTIM] = spawn_instance(args, mf.name, VICTIM)
        wait_healthy(inst_addrs[VICTIM])
        fired, _seen = wait_for_instance(gw_addr, VICTIM, "phase-c")
        total_ok += fired
        print(f"phase C rebalanced: victim {VICTIM} back in split")
        _, gst = http("GET", gw_addr, "/status")
        assert gst["active_set"][VICTIM] == "active", gst["active_set"]

        # Manifest removal under live traffic: the victim drains and
        # retires; nothing new lands on it.
        m_less = dict(manifest)
        m_less["instances"] = [a for i, a in enumerate(inst_addrs)
                               if i != VICTIM]
        m_less["cluster"] = dict(manifest["cluster"],
                                 n_instances=N_INSTANCES - 1)
        status, resp = http("POST", gw_addr, "/manifest", m_less)
        assert status == 200 and resp["removed"] == 1, resp
        d = fire_batch(gw_addr, 8, "manifest-less")
        total_ok += 8
        assert all(i != VICTIM for i in d), \
            f"dispatch landed on a manifest-removed instance: {d}"
        _, gst = http("GET", gw_addr, "/status")
        assert gst["active_set"][VICTIM] in ("draining", "retired"), \
            gst["active_set"]

        # Manifest re-add: retired slot reopens and the health prober
        # re-admits the (still running) daemon.
        status, resp = http("POST", gw_addr, "/manifest", manifest)
        assert status == 200, resp
        fired, _seen = wait_for_instance(gw_addr, VICTIM, "manifest-readd")
        total_ok += fired
        _, gst = http("GET", gw_addr, "/status")
        assert gst["active_set"][VICTIM] == "active", gst["active_set"]
        states = {ev["state"] for ev in gst["lifecycle"]}
        causes = {ev["cause"] for ev in gst["lifecycle"]}
        assert "draining" in states and "retired" in states, gst["lifecycle"]
        assert "manifest-remove" in causes and "manifest-add" in causes, \
            gst["lifecycle"]

        # Conservation on the wire: every accepted request completed —
        # in JSON status and in the Prometheus scrape alike.
        assert gst["completed"] == total_ok, (gst["completed"], total_ok)
        assert gst["rejected"] == 0, gst
        gm, _ = scrape_metrics(gw_addr)
        assert gm[("block_e2e_seconds_count", ())] == total_ok, gm
        assert gm[("block_slots", (("state", "active"),))] == N_INSTANCES

        print(f"elasticity-smoke OK: {total_ok} requests, scheduler "
              f"{args.scheduler}, kill/restart + manifest add/remove "
              f"re-admission exercised")
    finally:
        shutdown_all(inst_addrs + [gw_addr], procs.values())


if __name__ == "__main__":
    sys.exit(main())
