#!/usr/bin/env python3
"""elasticity-smoke: kill, restart, and re-admit real instance daemons.

Brings up 3 sim-clock instance daemons + 1 gateway (``block serve``) on
loopback and drives the wire side of the elasticity lifecycle:

* phase A — healthy traffic lands on all 3 instances;
* phase B — one daemon is SIGKILLed between batches; every subsequent
  request still returns 200 (bounce -> redispatch), i.e. no accepted
  request is dropped, and nothing lands on the dead slot;
* phase C — the daemon is restarted on the same port and the gateway
  re-admits it (health probe or status re-sync); the dispatch split
  rebalances onto the rejoined instance;
* manifest — ``POST /manifest`` removes the instance under live traffic
  (drain -> retire, no new dispatches) and a second update re-adds it
  (retired -> backup -> probed -> active);
* telemetry — ``GET /status`` exposes the live active set and the
  lifecycle transition timeline; ``GET /healthz`` answers on instances.

Usage: elasticity_smoke.py [--scheduler block|min-qpm] [--bin PATH]
"""

import argparse
import json
import subprocess
import sys
import tempfile
import threading
import time
import urllib.error
import urllib.request

BASE_PORT = 18800
N_INSTANCES = 3
MAX_NEW = 16
VICTIM = 2


def http(method, addr, path, body=None, timeout=30):
    data = json.dumps(body).encode() if body is not None else None
    req = urllib.request.Request(
        f"http://{addr}{path}", data=data, method=method,
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=timeout) as resp:
        return resp.status, json.loads(resp.read().decode() or "{}")


def wait_healthy(addr, deadline=30.0):
    t0 = time.time()
    while time.time() - t0 < deadline:
        try:
            status, body = http("GET", addr, "/health", timeout=2)
            if status == 200 and body.get("ok"):
                return
        except (urllib.error.URLError, ConnectionError, OSError):
            pass
        time.sleep(0.2)
    raise SystemExit(f"{addr} did not come up within {deadline}s")


def fire_batch(gw_addr, n, tag):
    """n concurrent /generate calls; returns the landing instances.

    Every call must return 200 with the full token budget — the
    no-dropped-requests assertion rides on this.
    """
    results, errors = [], []

    def fire(i):
        try:
            status, body = http(
                "POST", gw_addr, "/generate",
                {"prompt": f"{tag} {i}", "prompt_tokens": 200,
                 "max_new": MAX_NEW}, timeout=120)
            assert status == 200, body
            assert body["tokens"] == MAX_NEW, body
            results.append(body["instance"])
        except Exception as e:  # noqa: BLE001 - smoke harness
            errors.append(f"{tag} request {i}: {e}")

    threads = [threading.Thread(target=fire, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    assert len(results) == n
    return results


def wait_for_instance(gw_addr, instance, tag, deadline=30.0, batch=6):
    """Fire small batches until `instance` serves again (rebalance)."""
    t0 = time.time()
    seen = []
    while time.time() - t0 < deadline:
        seen = fire_batch(gw_addr, batch, tag)
        if instance in seen:
            return seen
        time.sleep(0.3)
    raise SystemExit(
        f"instance {instance} never rejoined the split within "
        f"{deadline}s (last batch: {seen})")


def spawn_instance(args, mf_name, index):
    return subprocess.Popen(
        [args.bin, "serve", "--role", "instance",
         "--manifest", mf_name, "--index", str(index)])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scheduler", default="block")
    ap.add_argument("--bin", default="target/release/block")
    ap.add_argument("--base-port", type=int, default=BASE_PORT)
    args = ap.parse_args()

    gw_addr = f"127.0.0.1:{args.base_port}"
    inst_addrs = [f"127.0.0.1:{args.base_port + 1 + i}"
                  for i in range(N_INSTANCES)]
    manifest = {
        "schema": "block-cluster/v1",
        "cluster": {
            "scheduler": args.scheduler,
            "frontends": 2,
            "sync_interval": 0.25,
            "n_instances": N_INSTANCES,
        },
        "instances": inst_addrs,
        "gateways": [gw_addr],
        "backend": "sim",
        "clock": "wall",
        "time_scale": 50.0,
    }
    mf = tempfile.NamedTemporaryFile("w", suffix=".json", delete=False)
    json.dump(manifest, mf)
    mf.close()

    procs = {}
    total_ok = 0
    try:
        for i in range(N_INSTANCES):
            procs[i] = spawn_instance(args, mf.name, i)
        procs["gw"] = subprocess.Popen(
            [args.bin, "serve", "--role", "gateway",
             "--manifest", mf.name, "--index", "0"])
        for addr in inst_addrs + [gw_addr]:
            wait_healthy(addr)

        # The O(1) liveness probe answers on every instance.
        for addr in inst_addrs:
            status, body = http("GET", addr, "/healthz", timeout=2)
            assert status == 200 and body.get("ok"), (addr, body)

        # Phase A: healthy traffic reaches all instances.
        a = fire_batch(gw_addr, 12, "phase-a")
        total_ok += 12
        split_a = [a.count(i) for i in range(N_INSTANCES)]
        print(f"phase A split: {split_a}")
        assert all(n >= 1 for n in split_a), f"skewed: {split_a}"

        # Phase B: kill one daemon between batches; traffic must keep
        # completing on the survivors with zero dropped requests.
        procs[VICTIM].kill()
        procs[VICTIM].wait()
        b = fire_batch(gw_addr, 12, "phase-b")
        total_ok += 12
        assert all(i != VICTIM for i in b), \
            f"dispatch landed on the dead instance: {b}"
        print(f"phase B split: {[b.count(i) for i in range(N_INSTANCES)]}")

        # The gateway exports the lifecycle vocabulary.
        _, gst = http("GET", gw_addr, "/status")
        assert len(gst["active_set"]) == N_INSTANCES, gst["active_set"]
        assert isinstance(gst["lifecycle"], list)
        for ev in gst["lifecycle"]:
            for field in ("time", "instance", "state", "cause"):
                assert field in ev, ev

        # Phase C: restart the daemon on the same port; the gateway
        # re-admits it and the split rebalances.
        procs[VICTIM] = spawn_instance(args, mf.name, VICTIM)
        wait_healthy(inst_addrs[VICTIM])
        c = wait_for_instance(gw_addr, VICTIM, "phase-c")
        total_ok += len(c)
        print(f"phase C rebalanced: victim {VICTIM} back in split")
        _, gst = http("GET", gw_addr, "/status")
        assert gst["active_set"][VICTIM] == "active", gst["active_set"]

        # Manifest removal under live traffic: the victim drains and
        # retires; nothing new lands on it.
        m_less = dict(manifest)
        m_less["instances"] = [a for i, a in enumerate(inst_addrs)
                               if i != VICTIM]
        m_less["cluster"] = dict(manifest["cluster"],
                                 n_instances=N_INSTANCES - 1)
        status, resp = http("POST", gw_addr, "/manifest", m_less)
        assert status == 200 and resp["removed"] == 1, resp
        d = fire_batch(gw_addr, 8, "manifest-less")
        total_ok += 8
        assert all(i != VICTIM for i in d), \
            f"dispatch landed on a manifest-removed instance: {d}"
        _, gst = http("GET", gw_addr, "/status")
        assert gst["active_set"][VICTIM] in ("draining", "retired"), \
            gst["active_set"]

        # Manifest re-add: retired slot reopens and the health prober
        # re-admits the (still running) daemon.
        status, resp = http("POST", gw_addr, "/manifest", manifest)
        assert status == 200, resp
        e = wait_for_instance(gw_addr, VICTIM, "manifest-readd")
        total_ok += len(e)
        _, gst = http("GET", gw_addr, "/status")
        assert gst["active_set"][VICTIM] == "active", gst["active_set"]
        states = {ev["state"] for ev in gst["lifecycle"]}
        causes = {ev["cause"] for ev in gst["lifecycle"]}
        assert "draining" in states and "retired" in states, gst["lifecycle"]
        assert "manifest-remove" in causes and "manifest-add" in causes, \
            gst["lifecycle"]

        # Conservation on the wire: every accepted request completed.
        assert gst["completed"] == total_ok, (gst["completed"], total_ok)
        assert gst["rejected"] == 0, gst

        print(f"elasticity-smoke OK: {total_ok} requests, scheduler "
              f"{args.scheduler}, kill/restart + manifest add/remove "
              f"re-admission exercised")
    finally:
        for addr in inst_addrs + [gw_addr]:
            try:
                http("POST", addr, "/shutdown", timeout=2)
            except Exception:  # noqa: BLE001
                pass
        deadline = time.time() + 5
        for p in procs.values():
            try:
                p.wait(timeout=max(0.1, deadline - time.time()))
            except subprocess.TimeoutExpired:
                p.kill()


if __name__ == "__main__":
    sys.exit(main())
