"""Shared plumbing for the wire smoke harnesses.

Every smoke script (serve, elasticity, gray, obs) drives the same stack
the same way: JSON HTTP against loopback daemons, a health-poll loop, a
concurrent ``/generate`` batch with the no-dropped-requests assertion,
and a teardown that always tries ``POST /shutdown`` first.  This module
is that plumbing, factored once, plus the Prometheus text-exposition
scraper/parser the ``/metrics`` checks are built on.

Only the standard library is used — the smoke scripts must run on a
bare CI runner.
"""

import json
import subprocess
import threading
import time
import urllib.error
import urllib.request

PROM_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


def http(method, addr, path, body=None, timeout=30):
    """JSON request/response against a loopback daemon."""
    data = json.dumps(body).encode() if body is not None else None
    req = urllib.request.Request(
        f"http://{addr}{path}", data=data, method=method,
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=timeout) as resp:
        return resp.status, json.loads(resp.read().decode() or "{}")


def http_text(addr, path, timeout=30):
    """GET returning the raw body + Content-Type (for /metrics)."""
    req = urllib.request.Request(f"http://{addr}{path}", method="GET")
    with urllib.request.urlopen(req, timeout=timeout) as resp:
        ctype = resp.headers.get("Content-Type", "")
        return resp.status, ctype, resp.read().decode()


def wait_healthy(addr, deadline=30.0):
    """Poll GET /health until the daemon answers ``{"ok": true}``."""
    t0 = time.time()
    while time.time() - t0 < deadline:
        try:
            status, body = http("GET", addr, "/health", timeout=2)
            if status == 200 and body.get("ok"):
                return
        except (urllib.error.URLError, ConnectionError, OSError):
            pass
        time.sleep(0.2)
    raise SystemExit(f"{addr} did not come up within {deadline}s")


def fire_batch(gw_addr, n, tag, prompt_tokens=200, max_new=16):
    """n concurrent /generate calls; returns the landing instances.

    Every call must return 200 with the full token budget — the
    no-dropped-requests assertion rides on this.
    """
    results, errors = [], []

    def fire(i):
        try:
            status, body = http(
                "POST", gw_addr, "/generate",
                {"prompt": f"{tag} {i}", "prompt_tokens": prompt_tokens,
                 "max_new": max_new}, timeout=120)
            assert status == 200, body
            assert body["tokens"] == max_new, body
            results.append(body["instance"])
        except Exception as e:  # noqa: BLE001 - smoke harness
            errors.append(f"{tag} request {i}: {e}")

    threads = [threading.Thread(target=fire, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    assert len(results) == n
    return results


def wait_for_instance(gw_addr, instance, tag, deadline=30.0, batch=6):
    """Fire small batches until `instance` serves again (rebalance).

    Returns ``(total_fired, last_batch)`` so callers can both keep
    their conservation count and inspect the rebalanced split.
    """
    t0 = time.time()
    seen = []
    total = 0
    while time.time() - t0 < deadline:
        seen = fire_batch(gw_addr, batch, tag)
        total += batch
        if instance in seen:
            return total, seen
        time.sleep(0.3)
    raise SystemExit(
        f"instance {instance} never rejoined the split within "
        f"{deadline}s (last batch: {seen})")


def parse_prometheus(text):
    """Parse a Prometheus text-format 0.0.4 page.

    Returns ``(samples, types)``: ``samples`` maps
    ``(name, (("label", "value"), ...))`` — labels sorted — to the
    float sample, ``types`` maps metric name to its declared TYPE.
    Raises AssertionError on any line the grammar does not allow.
    """
    samples, types = {}, {}
    for line in text.splitlines():
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            assert len(parts) == 4, f"bad TYPE line: {line!r}"
            assert parts[3] in ("counter", "gauge", "histogram",
                                "summary", "untyped"), line
            types[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue  # HELP / comments
        metric, _, value = line.rpartition(" ")
        assert metric, f"bad sample line: {line!r}"
        float(value)  # must parse
        if "{" in metric:
            name, _, rest = metric.partition("{")
            assert rest.endswith("}"), f"bad labels: {line!r}"
            labels = []
            body = rest[:-1]
            if body:
                for pair in body.split(","):
                    k, _, v = pair.partition("=")
                    assert v.startswith('"') and v.endswith('"'), line
                    labels.append((k, v[1:-1]))
            key = (name, tuple(sorted(labels)))
        else:
            key = (metric, ())
        assert key not in samples, f"duplicate sample: {line!r}"
        samples[key] = float(value)
    assert types, "no TYPE declarations in exposition"
    return samples, types


def scrape_metrics(addr):
    """GET /metrics, assert the exposition contract, return samples.

    Checks the Prometheus content type and that the page parses under
    :func:`parse_prometheus`; returns ``(samples, types)``.
    """
    status, ctype, text = http_text(addr, "/metrics")
    assert status == 200, (addr, status)
    assert ctype == PROM_CONTENT_TYPE, (addr, ctype)
    samples, types = parse_prometheus(text)
    for (name, _labels) in samples:
        base = name
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix) and name[:-len(suffix)] in types:
                base = name[:-len(suffix)]
        assert base in types, f"{addr}: sample {name} missing TYPE"
    return samples, types


def sum_samples(samples, name):
    """Sum every sample of `name` across its label sets."""
    return sum(v for (n, _), v in samples.items() if n == name)


def shutdown_all(addrs, procs, grace=5.0):
    """Best-effort POST /shutdown, then wait (or kill) the daemons."""
    for addr in addrs:
        try:
            http("POST", addr, "/shutdown", timeout=2)
        except Exception:  # noqa: BLE001
            pass
    deadline = time.time() + grace
    for p in procs:
        try:
            p.wait(timeout=max(0.1, deadline - time.time()))
        except subprocess.TimeoutExpired:
            p.kill()
