#!/usr/bin/env python3
"""gray-smoke: wedge a real instance daemon and watch the gateway cope.

Brings up 2 sim-clock instance daemons + 1 gateway (``block serve``) on
loopback with predictive straggler detection enabled and tight wire
budgets, then drives the gray-failure path end to end:

* phase A — healthy traffic lands on both instances;
* freeze — one daemon is SIGSTOPped between batches: it passes TCP
  accept (the kernel completes handshakes) but never answers, the
  textbook wedged-not-dead gray failure.  The gateway's status pull
  times out and quarantines the slot (Active -> Degraded,
  cause ``status-fail``);
* escalate — three consecutive ``healthz`` misses on the Degraded slot
  escalate it to Failed (cause ``gray-fail``); traffic throughout keeps
  completing on the survivor with zero accepted requests dropped;
* thaw — SIGCONT wakes the daemon; the health prober re-admits the
  Failed slot (cause ``rejoin``) and the dispatch split rebalances;
* conservation — ``GET /status`` shows every accepted request
  completed: no drops, no 504s, no sheds.

Usage: gray_smoke.py [--scheduler block] [--bin PATH] [--base-port N]
"""

import argparse
import json
import signal
import subprocess
import sys
import tempfile
import time

from smoke_common import (fire_batch, http, scrape_metrics, shutdown_all,
                          wait_for_instance, wait_healthy)

BASE_PORT = 18900
N_INSTANCES = 2
VICTIM = 1
SURVIVOR = 0


def wait_state(gw_addr, instance, states, tag, deadline=60.0):
    """Poll /status until active_set[instance] is in `states`."""
    t0 = time.time()
    last = None
    while time.time() - t0 < deadline:
        _, gst = http("GET", gw_addr, "/status")
        last = gst["active_set"][instance]
        if last in states:
            return gst
        time.sleep(0.2)
    raise SystemExit(
        f"{tag}: instance {instance} never reached {states} within "
        f"{deadline}s (last state: {last})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scheduler", default="block")
    ap.add_argument("--bin", default="target/release/block")
    ap.add_argument("--base-port", type=int, default=BASE_PORT)
    args = ap.parse_args()

    gw_addr = f"127.0.0.1:{args.base_port}"
    inst_addrs = [f"127.0.0.1:{args.base_port + 1 + i}"
                  for i in range(N_INSTANCES)]
    manifest = {
        "schema": "block-cluster/v1",
        "cluster": {
            "scheduler": args.scheduler,
            "frontends": 2,
            "sync_interval": 0.25,
            "n_instances": N_INSTANCES,
            # A wedged daemon is detected by its failed status pull;
            # completions feed the residual tracker as usual.
            "detect": {"enabled": True},
        },
        "instances": inst_addrs,
        "gateways": [gw_addr],
        "backend": "sim",
        "clock": "wall",
        "time_scale": 50.0,
        # Tight wire budgets: a frozen peer costs ~1s per RPC, not the
        # OS default (minutes), so quarantine and escalation are fast.
        "wire": {
            "connect_timeout": 1.0,
            "read_timeout": 1.0,
            "write_timeout": 1.0,
        },
    }
    mf = tempfile.NamedTemporaryFile("w", suffix=".json", delete=False)
    json.dump(manifest, mf)
    mf.close()

    procs = {}
    total_ok = 0
    try:
        for i in range(N_INSTANCES):
            procs[i] = subprocess.Popen(
                [args.bin, "serve", "--role", "instance",
                 "--manifest", mf.name, "--index", str(i)])
        procs["gw"] = subprocess.Popen(
            [args.bin, "serve", "--role", "gateway",
             "--manifest", mf.name, "--index", "0"])
        for addr in inst_addrs + [gw_addr]:
            wait_healthy(addr)

        # Phase A: healthy traffic reaches both instances; the status
        # export carries the detection telemetry.
        a = fire_batch(gw_addr, 10, "phase-a")
        total_ok += 10
        split_a = [a.count(i) for i in range(N_INSTANCES)]
        print(f"phase A split: {split_a}")
        assert all(n >= 1 for n in split_a), f"skewed: {split_a}"
        _, gst = http("GET", gw_addr, "/status")
        assert gst["detect_enabled"] is True, gst
        assert gst["timed_out"] == 0 and gst["shed"] == 0, gst

        # Freeze: SIGSTOP the victim between batches.  The daemon still
        # accepts TCP but never answers — the wedged gray case.  The
        # gateway's next status pull times out and quarantines the slot.
        procs[VICTIM].send_signal(signal.SIGSTOP)
        gst = wait_state(gw_addr, VICTIM, ("degraded", "failed"), "freeze")
        print(f"frozen victim state: {gst['active_set'][VICTIM]}")
        assert any(ev["state"] == "degraded"
                   and ev["cause"] == "status-fail"
                   for ev in gst["lifecycle"]), gst["lifecycle"]

        # Traffic during the freeze completes on the survivor: a gray
        # failure slows one slot, it must not drop accepted requests.
        b = fire_batch(gw_addr, 10, "frozen")
        total_ok += 10
        assert all(i == SURVIVOR for i in b), \
            f"dispatch landed on the wedged instance: {b}"
        print(f"frozen split: {[b.count(i) for i in range(N_INSTANCES)]}")

        # Escalate: three consecutive healthz misses on the Degraded
        # slot promote it to Failed (gray-fail).
        gst = wait_state(gw_addr, VICTIM, ("failed",), "escalate")
        assert any(ev["state"] == "failed" and ev["cause"] == "gray-fail"
                   for ev in gst["lifecycle"]), gst["lifecycle"]
        print("victim escalated: degraded -> failed (gray-fail)")
        # The scrape exposes the quarantine: one slot out of rotation.
        gm, _ = scrape_metrics(gw_addr)
        assert gm[("block_slots", (("state", "active"),))] \
            == N_INSTANCES - 1, gm

        # Thaw: SIGCONT wakes the daemon; the health prober re-admits
        # the Failed slot and the split rebalances onto it.
        procs[VICTIM].send_signal(signal.SIGCONT)
        gst = wait_state(gw_addr, VICTIM, ("active",), "thaw")
        assert any(ev["state"] == "active" and ev["cause"] == "rejoin"
                   for ev in gst["lifecycle"]), gst["lifecycle"]
        fired, _seen = wait_for_instance(gw_addr, VICTIM, "thawed")
        total_ok += fired
        print("victim re-admitted: back in the dispatch split")

        # Conservation on the wire: every accepted request completed —
        # nothing dropped, timed out, or shed across the whole episode.
        _, gst = http("GET", gw_addr, "/status")
        assert gst["completed"] == total_ok, (gst["completed"], total_ok)
        assert gst["rejected"] == 0, gst
        assert gst["timed_out"] == 0, gst
        assert gst["shed"] == 0, gst

        print(f"gray-smoke OK: {total_ok} requests, scheduler "
              f"{args.scheduler}, SIGSTOP quarantine -> gray-fail "
              f"escalation -> SIGCONT re-admission exercised")
    finally:
        # A still-frozen victim cannot honor /shutdown: thaw first.
        for i in range(N_INSTANCES):
            try:
                procs[i].send_signal(signal.SIGCONT)
            except Exception:  # noqa: BLE001
                pass
        shutdown_all(inst_addrs + [gw_addr], procs.values())


if __name__ == "__main__":
    sys.exit(main())
