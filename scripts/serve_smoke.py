#!/usr/bin/env python3
"""serve-smoke: bring up the real serving stack and pound on it.

Launches 2 sim-clock instance daemons + 1 gateway (``block serve``) on
loopback, fires concurrent ``POST /generate`` requests, and asserts

* completeness — every request returns 200 with the full token budget;
* a balanced dispatch split across the instances;
* a well-formed ``/status`` on every component (the gateway's telemetry
  counters and the instances' full InstanceStatus schema);
* a live ``GET /metrics`` on every component in the Prometheus text
  exposition format, consistent with the JSON counters.

Usage: serve_smoke.py [--scheduler block|min-qpm|...] [--bin PATH]
"""

import argparse
import json
import subprocess
import sys
import tempfile

from smoke_common import (fire_batch, http, scrape_metrics, shutdown_all,
                          sum_samples, wait_healthy)

BASE_PORT = 18600
N_INSTANCES = 2
N_REQUESTS = 16
MAX_NEW = 16


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scheduler", default="block")
    ap.add_argument("--bin", default="target/release/block")
    ap.add_argument("--base-port", type=int, default=BASE_PORT)
    args = ap.parse_args()

    gw_addr = f"127.0.0.1:{args.base_port}"
    inst_addrs = [f"127.0.0.1:{args.base_port + 1 + i}"
                  for i in range(N_INSTANCES)]
    manifest = {
        "schema": "block-cluster/v1",
        "cluster": {
            "scheduler": args.scheduler,
            "frontends": 1,
            "sync_interval": 0.25,
            "n_instances": N_INSTANCES,
        },
        "instances": inst_addrs,
        "gateways": [gw_addr],
        "backend": "sim",
        "clock": "wall",
        # Fast-forward the sim clock so the roofline-model "GPU" serves
        # the batch in well under a second of wall time.
        "time_scale": 50.0,
    }
    mf = tempfile.NamedTemporaryFile("w", suffix=".json", delete=False)
    json.dump(manifest, mf)
    mf.close()

    procs = []
    try:
        for i in range(N_INSTANCES):
            procs.append(subprocess.Popen(
                [args.bin, "serve", "--role", "instance",
                 "--manifest", mf.name, "--index", str(i)]))
        procs.append(subprocess.Popen(
            [args.bin, "serve", "--role", "gateway",
             "--manifest", mf.name, "--index", "0"]))
        for addr in inst_addrs + [gw_addr]:
            wait_healthy(addr)

        # Concurrent generation.
        results = fire_batch(gw_addr, N_REQUESTS, "smoke", max_new=MAX_NEW)

        split = [results.count(i) for i in range(N_INSTANCES)]
        print(f"dispatch split: {split}")
        assert all(n >= N_REQUESTS // 4 for n in split), \
            f"dispatch split too skewed: {split}"

        # Gateway telemetry is schema-complete.
        _, gst = http("GET", gw_addr, "/status")
        assert gst["role"] == "gateway"
        assert gst["scheduler"] == args.scheduler
        assert gst["completed"] == N_REQUESTS, gst
        assert sum(gst["instance_dispatches"]) == N_REQUESTS
        assert sum(gst["frontend_dispatches"]) == N_REQUESTS
        assert gst["bounced"] == 0 and gst["rejected"] == 0
        assert gst["summary"]["mean_e2e"] > 0
        # The uniform telemetry sub-object mirrors the simulator's
        # envelope vocabulary.
        tel = gst["telemetry"]
        assert tel["completed"] == N_REQUESTS, tel
        assert tel["wall_time_s"] > 0
        assert sum(tel["frontend_dispatches"]) == N_REQUESTS
        assert tel["slot_states"]["active"] == N_INSTANCES, tel

        # The gateway's Prometheus scrape agrees with its JSON status.
        gm, gtypes = scrape_metrics(gw_addr)
        assert gtypes["block_dispatches_total"] == "counter"
        assert gtypes["block_e2e_seconds"] == "histogram"
        assert sum_samples(gm, "block_dispatches_total") == N_REQUESTS
        assert sum_samples(gm, "block_finished_requests_total") == N_REQUESTS
        assert gm[("block_e2e_seconds_count", ())] == N_REQUESTS
        assert gm[("block_in_flight", ())] == 0
        assert gm[("block_slots", (("state", "active"),))] == N_INSTANCES

        # Instances export the full status schema + daemon counters,
        # and their own /metrics scrape matches.
        for idx, addr in enumerate(inst_addrs):
            _, ist = http("GET", addr, "/status")
            for field in ("now", "epoch", "free_blocks", "total_blocks",
                          "watermark_blocks", "running", "waiting",
                          "total_preemptions"):
                assert field in ist, (addr, field)
            assert ist["role"] == "instance"
            assert ist["requests_enqueued"] > 0
            assert ist["requests_completed"] > 0
            assert ist["tokens_generated"] > 0
            im, itypes = scrape_metrics(addr)
            assert itypes["block_requests_completed_total"] == "counter"
            assert im[("block_requests_completed_total", ())] \
                == ist["requests_completed"], (addr, im)
            assert im[("block_requests_enqueued_total", ())] \
                == ist["requests_enqueued"], (addr, im)
            assert im[("block_tokens_generated_total", ())] \
                == ist["tokens_generated"], (addr, im)
            assert im[("block_engine_free_blocks", ())] \
                <= im[("block_engine_total_blocks", ())], (addr, im)
            assert split[idx] == ist["requests_completed"], \
                (split, idx, ist["requests_completed"])

        # The tagger path answers.
        _, pred = http("POST", gw_addr, "/predict",
                       {"prompt": "how long will this take?"})
        assert pred["predicted_tokens"] >= 1

        print(f"serve-smoke OK: {N_REQUESTS} requests, scheduler "
              f"{args.scheduler}, split {split}, /metrics consistent")
    finally:
        shutdown_all(inst_addrs + [gw_addr], procs)


if __name__ == "__main__":
    sys.exit(main())
