#!/usr/bin/env python3
"""serve-smoke: bring up the real serving stack and pound on it.

Launches 2 sim-clock instance daemons + 1 gateway (``block serve``) on
loopback, fires concurrent ``POST /generate`` requests, and asserts

* completeness — every request returns 200 with the full token budget;
* a balanced dispatch split across the instances;
* a well-formed ``/status`` on every component (the gateway's telemetry
  counters and the instances' full InstanceStatus schema).

Usage: serve_smoke.py [--scheduler block|min-qpm|...] [--bin PATH]
"""

import argparse
import json
import subprocess
import sys
import tempfile
import threading
import time
import urllib.error
import urllib.request

BASE_PORT = 18600
N_INSTANCES = 2
N_REQUESTS = 16
MAX_NEW = 16


def http(method, addr, path, body=None, timeout=30):
    data = json.dumps(body).encode() if body is not None else None
    req = urllib.request.Request(
        f"http://{addr}{path}", data=data, method=method,
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=timeout) as resp:
        return resp.status, json.loads(resp.read().decode() or "{}")


def wait_healthy(addr, deadline=30.0):
    t0 = time.time()
    while time.time() - t0 < deadline:
        try:
            status, body = http("GET", addr, "/health", timeout=2)
            if status == 200 and body.get("ok"):
                return
        except (urllib.error.URLError, ConnectionError, OSError):
            pass
        time.sleep(0.2)
    raise SystemExit(f"{addr} did not come up within {deadline}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scheduler", default="block")
    ap.add_argument("--bin", default="target/release/block")
    ap.add_argument("--base-port", type=int, default=BASE_PORT)
    args = ap.parse_args()

    gw_addr = f"127.0.0.1:{args.base_port}"
    inst_addrs = [f"127.0.0.1:{args.base_port + 1 + i}"
                  for i in range(N_INSTANCES)]
    manifest = {
        "schema": "block-cluster/v1",
        "cluster": {
            "scheduler": args.scheduler,
            "frontends": 1,
            "sync_interval": 0.25,
            "n_instances": N_INSTANCES,
        },
        "instances": inst_addrs,
        "gateways": [gw_addr],
        "backend": "sim",
        "clock": "wall",
        # Fast-forward the sim clock so the roofline-model "GPU" serves
        # the batch in well under a second of wall time.
        "time_scale": 50.0,
    }
    mf = tempfile.NamedTemporaryFile("w", suffix=".json", delete=False)
    json.dump(manifest, mf)
    mf.close()

    procs = []
    try:
        for i in range(N_INSTANCES):
            procs.append(subprocess.Popen(
                [args.bin, "serve", "--role", "instance",
                 "--manifest", mf.name, "--index", str(i)]))
        procs.append(subprocess.Popen(
            [args.bin, "serve", "--role", "gateway",
             "--manifest", mf.name, "--index", "0"]))
        for addr in inst_addrs + [gw_addr]:
            wait_healthy(addr)

        # Concurrent generation.
        results, errors = [], []

        def fire(i):
            try:
                status, body = http(
                    "POST", gw_addr, "/generate",
                    {"prompt": f"smoke {i}", "prompt_tokens": 200,
                     "max_new": MAX_NEW}, timeout=120)
                assert status == 200, body
                assert body["tokens"] == MAX_NEW, body
                results.append(body["instance"])
            except Exception as e:  # noqa: BLE001 - smoke harness
                errors.append(f"request {i}: {e}")

        threads = [threading.Thread(target=fire, args=(i,))
                   for i in range(N_REQUESTS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors, errors
        assert len(results) == N_REQUESTS

        split = [results.count(i) for i in range(N_INSTANCES)]
        print(f"dispatch split: {split}")
        assert all(n >= N_REQUESTS // 4 for n in split), \
            f"dispatch split too skewed: {split}"

        # Gateway telemetry is schema-complete.
        _, gst = http("GET", gw_addr, "/status")
        assert gst["role"] == "gateway"
        assert gst["scheduler"] == args.scheduler
        assert gst["completed"] == N_REQUESTS, gst
        assert sum(gst["instance_dispatches"]) == N_REQUESTS
        assert sum(gst["frontend_dispatches"]) == N_REQUESTS
        assert gst["bounced"] == 0 and gst["rejected"] == 0
        assert gst["summary"]["mean_e2e"] > 0

        # Instances export the full status schema + daemon counters.
        for addr in inst_addrs:
            _, ist = http("GET", addr, "/status")
            for field in ("now", "epoch", "free_blocks", "total_blocks",
                          "watermark_blocks", "running", "waiting",
                          "total_preemptions"):
                assert field in ist, (addr, field)
            assert ist["role"] == "instance"
            assert ist["requests_enqueued"] > 0
            assert ist["requests_completed"] > 0
            assert ist["tokens_generated"] > 0

        # The tagger path answers.
        _, pred = http("POST", gw_addr, "/predict",
                       {"prompt": "how long will this take?"})
        assert pred["predicted_tokens"] >= 1

        print(f"serve-smoke OK: {N_REQUESTS} requests, scheduler "
              f"{args.scheduler}, split {split}")
    finally:
        for addr in inst_addrs + [gw_addr]:
            try:
                http("POST", addr, "/shutdown", timeout=2)
            except Exception:  # noqa: BLE001
                pass
        deadline = time.time() + 5
        for p in procs:
            try:
                p.wait(timeout=max(0.1, deadline - time.time()))
            except subprocess.TimeoutExpired:
                p.kill()


if __name__ == "__main__":
    sys.exit(main())
