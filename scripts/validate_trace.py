#!/usr/bin/env python3
"""validate-trace: schema-check the artifacts of ``simulate --trace``.

Validates the pair of decision-trace artifacts the simulator dumps:

* the Chrome trace-event JSON (``--trace FILE``) — Perfetto-loadable
  shape: a ``traceEvents`` list of ``ph:"X"`` complete slices (annotated
  decisions, spanning arrival -> finish on the chosen instance's track)
  and ``ph:"i"`` instants (unannotated ones);
* the raw JSONL decision log (``FILE.jsonl``) — one decision per line,
  checked for schema and for the scheduler's own invariants: the chosen
  instance is the candidate-set argmin, the annotated instance is the
  chosen one on fault-free runs, and ``residual == actual - predicted``.

With ``--result out.json`` (the ``--json`` envelope of the same run)
the artifact counts are cross-checked against the run's obs summary.

Usage: validate_trace.py TRACE.json TRACE.jsonl [--result OUT.json]
                         [--allow-redispatch]
"""

import argparse
import json
import sys

NUM = (int, float)


def fail(msg):
    raise SystemExit(f"validate-trace: {msg}")


def validate_chrome(path):
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail(f"{path}: traceEvents must be a non-empty list")
    complete = 0
    for ev in events:
        for field in ("name", "cat", "pid", "ph", "tid", "ts", "args"):
            if field not in ev:
                fail(f"{path}: event missing {field}: {ev}")
        if ev["cat"] != "dispatch":
            fail(f"{path}: unexpected category: {ev}")
        args = ev["args"]
        if "id" not in args or "chosen" not in args:
            fail(f"{path}: args missing id/chosen: {ev}")
        if ev["ph"] == "X":
            complete += 1
            if not (isinstance(ev["dur"], NUM) and ev["dur"] >= 0):
                fail(f"{path}: X event needs dur >= 0: {ev}")
            if not isinstance(args.get("actual_e2e"), NUM):
                fail(f"{path}: X event lacks actual_e2e: {ev}")
            if ev["tid"] != args.get("actual_instance", ev["tid"]):
                fail(f"{path}: X event off its instance track: {ev}")
        elif ev["ph"] == "i":
            if ev.get("s") != "t":
                fail(f"{path}: instant needs scope 't': {ev}")
        else:
            fail(f"{path}: unexpected phase {ev['ph']!r}")
    return len(events), complete


def validate_jsonl(path, allow_redispatch):
    n = annotated = 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                fail(f"{path}:{lineno}: bad JSON ({e})")
            n += 1
            for field in ("id", "arrival", "t", "frontend", "chosen",
                          "overhead", "candidates"):
                if field not in rec:
                    fail(f"{path}:{lineno}: missing {field}")
            if rec["t"] < rec["arrival"]:
                fail(f"{path}:{lineno}: decision precedes arrival")
            cands = rec["candidates"]
            if cands:
                for c in cands:
                    if not isinstance(c.get("instance"), int) \
                            or not isinstance(c.get("predicted_e2e"), NUM):
                        fail(f"{path}:{lineno}: malformed candidate {c}")
                by_inst = {c["instance"]: c["predicted_e2e"] for c in cands}
                if rec["chosen"] not in by_inst:
                    fail(f"{path}:{lineno}: chosen not in candidate set")
                best = min(by_inst.values())
                if by_inst[rec["chosen"]] > best:
                    fail(f"{path}:{lineno}: chosen is not the argmin "
                         f"({by_inst[rec['chosen']]} > {best})")
            if "actual_e2e" in rec:
                annotated += 1
                if "predicted_e2e" in rec:
                    want = rec["actual_e2e"] - rec["predicted_e2e"]
                    if abs(rec.get("residual", want) - want) > 1e-9:
                        fail(f"{path}:{lineno}: residual mismatch")
                if not allow_redispatch \
                        and rec.get("actual_instance") != rec["chosen"]:
                    fail(f"{path}:{lineno}: annotated instance "
                         f"{rec.get('actual_instance')} != chosen "
                         f"{rec['chosen']} on a fault-free run")
    if n == 0:
        fail(f"{path}: no decision records")
    return n, annotated


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="Chrome trace-event JSON")
    ap.add_argument("jsonl", help="raw JSONL decision log")
    ap.add_argument("--result", help="--json result envelope to cross-check")
    ap.add_argument("--allow-redispatch", action="store_true",
                    help="run had faults: annotated instance may differ "
                         "from the (superseded) chosen one")
    args = ap.parse_args()

    events, complete = validate_chrome(args.trace)
    decisions, annotated = validate_jsonl(args.jsonl,
                                          args.allow_redispatch)
    if events != decisions:
        fail(f"artifact mismatch: {events} trace events vs "
             f"{decisions} JSONL decisions")
    if complete != annotated:
        fail(f"artifact mismatch: {complete} complete slices vs "
             f"{annotated} annotated decisions")

    if args.result:
        with open(args.result) as f:
            res = json.load(f)
        obs = res.get("obs")
        if not obs:
            fail(f"{args.result}: no obs summary in the envelope")
        if obs["decisions"] != decisions or obs["annotated"] != annotated:
            fail(f"envelope disagrees with artifacts: {obs} vs "
                 f"{decisions}/{annotated}")
        if obs["flight_recorded"] < decisions:
            fail("flight recorder saw fewer events than decisions")
        tel = res.get("telemetry")
        if not tel or tel.get("events_processed", 0) <= 0:
            fail(f"{args.result}: telemetry envelope missing/empty")

    print(f"validate-trace OK: {decisions} decisions ({annotated} "
          f"annotated), {events} trace events ({complete} complete)")


if __name__ == "__main__":
    sys.exit(main())
